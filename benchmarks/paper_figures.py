"""One function per paper table/figure (Figs 14-19 + Table I).

Each returns a list of CSV rows ``(name, us_per_call, derived)`` where
``us_per_call`` is the modeled/measured latency in microseconds and
``derived`` carries the figure's headline quantity (speedup, ratio, …).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import hwmodel

from .common import all_stats, bench_matrices, gmean

Row = Tuple[str, float, float]


def table1() -> List[Row]:
    """Table I: generated-matrix statistics vs paper targets."""
    rows = []
    for m, (mid, name, dim, nnz, nnz_av, sigma) in zip(
            bench_matrices(), __import__("benchmarks.common", fromlist=["TABLE1"]).TABLE1):
        err = abs(m.sigma - sigma) / max(sigma, 1e-9)
        rows.append((f"table1/{name}", 0.0, round(err, 4)))
    return rows


def fig14_performance() -> List[Row]:
    """Fig 14: speedup vs GPU baseline for SPLIM / SAM / SpaceA / ReFlip."""
    stats = all_stats()
    cal = hwmodel.calibrate(stats)
    rows = []
    sp_gpu, sp_sam, sp_spacea, sp_reflip = [], [], [], []
    for s, m in zip(stats, bench_matrices()):
        t_splim = hwmodel.splim_latency(s)["total"]
        t_gpu = hwmodel.gpu_latency(s) * cal["gpu_perf"]
        t_sam = hwmodel.sam_latency(s) * cal["sam_perf"]
        t_spa = hwmodel.spacea_latency(s) * cal["spacea_perf"]
        t_ref = hwmodel.reflip_latency(s) * cal["reflip_perf"]
        sp_gpu.append(t_gpu / t_splim)
        sp_sam.append(t_gpu / t_sam)
        sp_spacea.append(t_gpu / t_spa)
        sp_reflip.append(t_gpu / t_ref)
        rows.append((f"fig14/{m.name}/splim", t_splim * 1e6,
                     round(t_gpu / t_splim, 2)))
    rows.append(("fig14/mean_speedup_vs_gpu", 0.0, round(float(np.mean(sp_gpu)), 2)))
    rows.append(("fig14/mean_vs_sam", 0.0,
                 round(float(np.mean(np.array(sp_gpu) / np.array(sp_sam))), 2)))
    rows.append(("fig14/mean_vs_spacea", 0.0,
                 round(float(np.mean(np.array(sp_gpu) / np.array(sp_spacea))), 2)))
    rows.append(("fig14/mean_vs_reflip", 0.0,
                 round(float(np.mean(np.array(sp_gpu) / np.array(sp_reflip))), 2)))
    return rows


def fig15_energy() -> List[Row]:
    stats = all_stats()
    cal = hwmodel.calibrate(stats)
    rows = []
    sv_gpu, sv_spacea, sv_reflip = [], [], []
    for s, m in zip(stats, bench_matrices()):
        e_splim = hwmodel.splim_energy(s)["total"]
        e_gpu = hwmodel.gpu_energy(s) * cal["gpu_energy"]
        e_spa = hwmodel.spacea_energy(s) * cal["spacea_energy"]
        e_ref = hwmodel.reflip_energy(s) * cal["reflip_energy"]
        sv_gpu.append(e_gpu / e_splim)
        sv_spacea.append(e_spa / e_splim)
        sv_reflip.append(e_ref / e_splim)
        rows.append((f"fig15/{m.name}/splim_J", e_splim * 1e6,
                     round(e_gpu / e_splim, 2)))
    rows.append(("fig15/mean_saving_vs_gpu", 0.0, round(float(np.mean(sv_gpu)), 2)))
    rows.append(("fig15/mean_saving_vs_spacea", 0.0, round(float(np.mean(sv_spacea)), 2)))
    rows.append(("fig15/mean_saving_vs_reflip", 0.0, round(float(np.mean(sv_reflip)), 2)))
    return rows


def fig16_utilization() -> List[Row]:
    """Fig 16: array utilization SPLIM vs COO-SPLIM — computed exactly from
    the format definitions (valid lanes / allocated lanes), not modeled."""
    rows = []
    gains = []
    for s, m in zip(all_stats(), bench_matrices()):
        util_splim = s.valid_products / float(s.k_a * s.k_b * s.n)
        util_coo = s.nnz_a / float(s.n) ** 2      # decompressed SpMV lanes
        gain = util_splim / util_coo
        gains.append(gain)
        rows.append((f"fig16/{m.name}", 0.0, round(gain, 1)))
    rows.append(("fig16/mean_utilization_gain", 0.0, round(float(np.mean(gains)), 1)))
    # energy breakdown (paper Fig 16b): array / leakage / io+ctrl fractions
    s0 = all_stats()[0]
    e = hwmodel.splim_energy(s0)
    for kk in ("array", "leakage", "io", "ctrl"):
        rows.append((f"fig16/energy_frac/{kk}", 0.0,
                     round(e[kk] / e["total"], 4)))
    return rows


def _scaled_stats(s, frac: float):
    import dataclasses as dc
    import math
    k = max(1, int(math.ceil(s.k_a * frac)))
    return dc.replace(
        s, nnz_a=int(s.nnz_a * frac), nnz_b=int(s.nnz_b * frac),
        k_a=k, k_b=k,
        valid_products=int(s.valid_products * frac * frac),
        nnz_c=max(1, int(s.nnz_c * (1 - (1 - frac ** 2) ** 1.0))))


def fig17_sparsity() -> List[Row]:
    """Fig 17: τ, τ/2, τ/3 — SPLIM speeds up as matrices get sparser."""
    rows = []
    reduction_half = []
    for s, m in zip(all_stats(), bench_matrices()):
        t1 = hwmodel.splim_latency(s)["total"]
        t2 = hwmodel.splim_latency(_scaled_stats(s, 0.5))["total"]
        t3 = hwmodel.splim_latency(_scaled_stats(s, 1 / 3))["total"]
        reduction_half.append(1 - t2 / t1)
        rows.append((f"fig17/{m.name}", t1 * 1e6,
                     round(t1 / t3, 2)))
    rows.append(("fig17/mean_exec_reduction_tau_half", 0.0,
                 round(float(np.mean(reduction_half)), 3)))
    return rows


def fig18_stddev() -> List[Row]:
    """Fig 18: σ, σ/2, σ/3 — narrower row distribution → smaller k → faster."""
    import dataclasses as dc
    import math
    rows = []
    for s, m in zip(all_stats(), bench_matrices()):
        nnz_av = s.nnz_a / s.n
        speeds = []
        t_base = None
        for div in (1, 2, 3):
            k = max(1, int(math.ceil(nnz_av + s.sigma / div)))
            s2 = dc.replace(s, k_a=k, k_b=k)
            t = hwmodel.splim_latency(s2)["total"]
            t_base = t_base or t
            speeds.append(t_base / t)
        rows.append((f"fig18/{m.name}", t_base * 1e6, round(speeds[-1], 2)))
    return rows


def fig19_scaling() -> List[Row]:
    """Fig 19: PE scaling 8 → 16 → 32."""
    import dataclasses as dc
    rows = []
    sp8, sp16 = [], []
    for s, m in zip(all_stats(), bench_matrices()):
        ts = {}
        for pes in (8, 16, 32):
            cfg = dc.replace(hwmodel.SplimConfig(), n_pes=pes)
            ts[pes] = hwmodel.splim_latency(s, cfg)["total"]
        sp8.append(ts[8] / ts[32])
        sp16.append(ts[16] / ts[32])
        rows.append((f"fig19/{m.name}", ts[32] * 1e6, round(ts[8] / ts[32], 2)))
    rows.append(("fig19/mean_speedup_32v8", 0.0, round(float(np.mean(sp8)), 2)))
    rows.append(("fig19/mean_speedup_32v16", 0.0, round(float(np.mean(sp16)), 2)))
    return rows
