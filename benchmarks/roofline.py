"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and derives the
three roofline terms per (arch × shape × mesh):

    compute    = HLO_FLOPs_per_device / 197e12            [bf16 peak / chip]
    memory     = HLO_bytes_per_device / 819e9              [HBM BW / chip]
    collective = collective_bytes_per_device / 50e9        [ICI / link]

Conventions: XLA compiles one SPMD program per device, so cost_analysis()
numbers are already per-chip; collective bytes are the summed *output-shape*
bytes of every all-gather/all-reduce/reduce-scatter/all-to-all/
collective-permute in the optimized HLO (ring transfer volume ≈ output size
× (n-1)/n ≈ output size). CPU-backend caveat recorded per row: XLA:CPU
canonicalizes bf16 dots to f32, so HLO_bytes (and some temps) are up to 2×
the TPU value — flagged, not corrected.

MODEL_FLOPS: train 6·N·D, prefill 2·N·D, decode 2·N_active·B (one token),
divided by chips (global→per-chip, to match the HLO numbers).

Usage:
    python -m benchmarks.roofline [--emit-md results/roofline.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip (TPU v5e)
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

RESULTS = Path(__file__).resolve().parents[1] / "results"


def model_flops_global(rec) -> float:
    n_act = rec["active_params"]
    d_tokens = rec["global_batch"] * rec["seq_len"]
    if rec["kind"] == "train":
        return 6.0 * n_act * d_tokens
    if rec["kind"] == "prefill":
        return 2.0 * n_act * d_tokens
    # decode: one new token per sequence (attention over the cache adds
    # 2·B·S·L·kv·hd·2 ~ included approximately via active params only)
    return 2.0 * n_act * rec["global_batch"]


def analyze(rec) -> dict:
    chips = rec["n_devices"]
    # prefer the trip-count-aware numbers (hlo_analysis.py); raw
    # HloCostAnalysis counts while bodies once (wrong by ~n_layers)
    flops = rec.get("hlo_flops_tc") or rec["hlo_flops"] or 0.0
    bytes_ = rec.get("hlo_bytes_tc") or rec["hlo_bytes"] or 0.0
    coll_d = rec.get("collective_bytes_tc") or rec["collective_bytes"]
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_ / HBM_BW
    coll = sum(coll_d.values())
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops_global(rec) / chips
    useful = mf / flops if flops else 0.0
    bound = max(terms.values())
    frac = t_comp / bound if bound else 0.0   # fraction of time that is MXU math
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "model_flops_per_chip": mf,
        "useful_flop_ratio": useful,
        "roofline_fraction": frac,
        "temp_bytes": rec["mem_per_device"]["temp_bytes"],
        "arg_bytes": rec["mem_per_device"]["argument_bytes"],
        "dispatch": rec.get("dispatch"),
    }


ADVICE = {
    ("compute", "train"): "cut recompute (remat policy) / raise MXU occupancy",
    ("compute", "prefill"): "halve causal-masked attention FLOPs via block skipping",
    ("compute", "decode"): "batch more sequences per step (MXU is idle at B·1)",
    ("memory", "train"): "fuse optimizer update into grad reduce; bf16 moments",
    ("memory", "prefill"): "keep KV in bf16 and widen VMEM tiles",
    ("memory", "decode"): "shrink KV reads: quantize cache / group-query sharing",
    ("collective", "train"): "overlap reduce-scatter with backward; int8 grads",
    ("collective", "prefill"): "shard seq (ring attention) to kill kv all-gathers",
    ("collective", "decode"): "replicate small weights over data to drop gathers",
}


def rows(pattern: str = "*.json"):
    recs = []
    for p in sorted((RESULTS / "dryrun").glob(pattern)):
        recs.append(analyze(json.loads(p.read_text())))
    return recs


def measured_rows():
    """Measured roofline per accumulation backend — the ``--only roofline``
    suite of benchmarks/run.py, built on ``repro.obs.roofline``.

    Per backend one evidence row ``micro/roofline_<backend>/<tag>``:
    ``us_per_call`` is the span-measured time of one jitted ``spgemm_coo``
    call, ``derived`` the achieved-vs-reference bandwidth fraction
    (modeled bytes from the planner's ``interm_*`` estimates over a
    measured streaming-copy anchor, see obs/roofline.py). CI gates
    derived ∈ (0, 1.5] for all six backends. One extra
    ``micro/roofline_ref_bw/<tag>`` row records the anchor itself (GB/s in
    the derived column) so trajectory regressions are attributable.

    When results/dryrun/*.json artifacts exist (repro.launch.dryrun), the
    static HLO analysis rows are appended as ``model/roofline/<...>``;
    absent artifacts are skipped silently — the measured rows never depend
    on them.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import ell_cols_from_dense, ell_rows_from_dense
    from repro.obs import roofline as rl
    out = []
    rng = np.random.default_rng(17)
    ref_bw = rl.measure_reference_bw()
    for tag, n, dens in [("n128", 128, 0.05)]:
        A = ((rng.random((n, n)) < dens)
             * rng.standard_normal((n, n))).astype(np.float32)
        B = ((rng.random((n, n)) < dens)
             * rng.standard_normal((n, n))).astype(np.float32)
        ka = max(1, int((A != 0).sum(0).max()))
        kb = max(1, int((B != 0).sum(1).max()))
        a = ell_rows_from_dense(jnp.asarray(A), ka)
        b = ell_cols_from_dense(jnp.asarray(B), kb)
        res = rl.measure_roofline(a, b, ref_bw=ref_bw)
        out.append((f"micro/roofline_ref_bw/{tag}", 0.0,
                    round(ref_bw / 1e9, 3)))
        for bk, r in res.items():
            out.append((f"micro/roofline_{bk}/{tag}", round(r["us"], 1),
                        round(r["frac"], 6)))
    for r in rows():                      # dryrun artifacts, when present
        out.append((f"model/roofline/{r['arch']}-{r['shape']}-{r['mesh']}",
                    round(r["t_compute_s"] * 1e6, 3),
                    round(r["roofline_fraction"], 4)))
    return out


def to_markdown(recs) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | advice |\n|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in recs:
        adv = ADVICE.get((r["dominant"], r["kind"]), "-")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.2f} | {adv} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-md", default="")
    ap.add_argument("--mesh", default="", help="filter: pod16x16 / pod2x16x16")
    args = ap.parse_args()
    recs = rows()
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    (RESULTS / "roofline.json").write_text(json.dumps(recs, indent=1))
    print(f"{'arch':24s} {'shape':12s} {'mesh':10s} "
          f"{'comp(s)':>9s} {'mem(s)':>9s} {'coll(s)':>9s} {'dom':>10s} "
          f"{'useful':>7s}")
    for r in recs:
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
              f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
              f"{r['t_collective_s']:9.2e} {r['dominant']:>10s} "
              f"{r['useful_flop_ratio']:7.2f}")
    if args.emit_md:
        Path(args.emit_md).write_text(to_markdown(recs))
        print(f"wrote {args.emit_md}")


if __name__ == "__main__":
    main()
