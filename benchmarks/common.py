"""Shared benchmark infrastructure: Table-I-matched synthetic matrices.

SuiteSparse is not downloadable offline, so each of the paper's 16 matrices
is regenerated as a random sparse matrix matching its published statistics
(Dim, nnz, nnz_av, σ of per-row nnz). Per-matrix *relative* behaviour in the
cost models is driven entirely by these statistics, which is exactly what
the paper's analyses (§III, §VI-C) key on. Matrices with ≤ ``EXACT_NNZ``
non-zeros run a real scipy SpGEMM for exact nnz(C); larger ones use the
standard random-intersection estimate (flagged "est").
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Tuple

import numpy as np
import scipy.sparse as sp

# (id, name, dim, nnz, nnz_av, sigma)  — paper Table I
TABLE1 = [
    (1, "pdb1HYS", 36_000, 4_300_000, 119.3, 31.86),
    (2, "rma10", 47_000, 2_300_000, 49.7, 27.78),
    (3, "bcsstk32", 45_000, 2_000_000, 45.2, 15.48),
    (4, "ct20stif", 52_000, 2_600_000, 49.7, 16.98),
    (5, "cant", 62_000, 4_000_000, 64.2, 14.06),
    (6, "crankseg_2", 64_000, 14_000_000, 222.0, 95.88),
    (7, "lhr71", 70_000, 1_500_000, 21.3, 26.32),
    (8, "consph", 83_000, 6_000_000, 72.1, 19.08),
    (9, "soc-sign-epinions", 132_000, 841_000, 6.4, 32.95),
    (10, "shipsec1", 141_000, 3_600_000, 25.3, 11.07),
    (11, "xenon2", 157_000, 3_900_000, 24.6, 4.07),
    (12, "ohne2", 181_000, 6_900_000, 37.9, 21.09),
    (13, "pwtk", 218_000, 11_500_000, 52.9, 4.74),
    (14, "stanford", 282_000, 2_300_000, 8.2, 166.33),
    (15, "cage14", 1_500_000, 27_100_000, 18.0, 5.37),
    (16, "webbase-1M", 1_000_000, 3_100_000, 3.1, 25.35),
]

EXACT_NNZ = 4_500_000   # exact scipy A·Aᵀ below this; estimate above


@dataclasses.dataclass
class BenchMatrix:
    mid: int
    name: str
    dim: int
    row_nnz: np.ndarray          # per-row counts (defines everything else)
    nnz: int
    sigma: float
    exact: bool

    @property
    def nnz_av(self) -> float:
        return self.nnz / self.dim


def _draw_row_counts(dim: int, nnz: int, sigma: float, rng) -> np.ndarray:
    mean = nnz / dim
    counts = rng.normal(mean, sigma, size=dim)
    counts = np.clip(np.round(counts), 0, dim).astype(np.int64)
    # exact-total adjustment
    diff = nnz - counts.sum()
    idx = rng.integers(0, dim, size=abs(int(diff)))
    np.add.at(counts, idx, 1 if diff > 0 else -1)
    return np.clip(counts, 0, dim)


@functools.lru_cache(maxsize=None)
def bench_matrices() -> Tuple[BenchMatrix, ...]:
    out = []
    for mid, name, dim, nnz, nnz_av, sigma in TABLE1:
        rng = np.random.default_rng(1000 + mid)
        counts = _draw_row_counts(dim, nnz, sigma, rng)
        out.append(BenchMatrix(mid=mid, name=name, dim=dim,
                               row_nnz=counts, nnz=int(counts.sum()),
                               sigma=float(counts.std()),
                               exact=nnz <= EXACT_NNZ))
    return tuple(out)


def build_scipy(m: BenchMatrix) -> sp.csr_matrix:
    """Materialize the matrix (rows get random column positions)."""
    rng = np.random.default_rng(2000 + m.mid)
    indptr = np.zeros(m.dim + 1, np.int64)
    np.cumsum(m.row_nnz, out=indptr[1:])
    indices = np.empty(indptr[-1], np.int32)
    for r in range(m.dim):
        lo, hi = indptr[r], indptr[r + 1]
        k = hi - lo
        if k:
            indices[lo:hi] = rng.choice(m.dim, size=k, replace=False) \
                if k < m.dim // 4 else rng.permutation(m.dim)[:k]
    data = rng.standard_normal(indptr[-1]).astype(np.float32)
    return sp.csr_matrix((data, indices, indptr), shape=(m.dim, m.dim))


def matrix_stats(m: BenchMatrix) -> "hwmodel.MatrixStats":
    """Stats for C = A·Aᵀ (the paper's benchmark kernel)."""
    from repro.core import hwmodel

    counts = m.row_nnz.astype(np.float64)
    # A·Aᵀ contracts over columns of A = rows of Aᵀ; with uniformly random
    # column placement, the expected per-column count equals nnz/dim but we
    # use the realized row counts for the transpose side.
    valid_products = int(np.sum(counts * counts))
    k = max(1, int(math.ceil(counts.mean() + counts.std())))
    if m.exact:
        a = build_scipy(m)
        c = (a @ a.T).tocsr()
        nnz_c = int(c.nnz)
    else:
        # random-intersection estimate: E[nnz_C] = n²(1 - exp(-P/n²))
        n2 = float(m.dim) ** 2
        nnz_c = int(n2 * (1.0 - math.exp(-valid_products / n2)))
    return hwmodel.MatrixStats(
        n=m.dim, nnz_a=m.nnz, nnz_b=m.nnz, k_a=k, k_b=k,
        valid_products=valid_products, nnz_c=nnz_c, sigma=m.sigma)


@functools.lru_cache(maxsize=None)
def all_stats():
    return tuple(matrix_stats(m) for m in bench_matrices())


def gmean(x) -> float:
    x = np.asarray(x, dtype=np.float64)
    return float(np.exp(np.mean(np.log(x))))
