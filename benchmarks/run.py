# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator: paper figures (modeled, Table-II-parameterized)
plus measured microbenchmarks of the executable JAX/Pallas implementation.

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig14,micro]
                                               [--json BENCH_accum.json]
                                               [--trace trace.json]

``--json PATH`` additionally dumps the collected rows as JSON — the CI smoke
mode is ``--only accum-backends --json BENCH_accum.json`` (tiny shapes, CPU),
which keeps a perf trajectory artifact on every push.

``--trace PATH`` enables the repro.obs tracer for the whole run and exports
a Chrome-trace JSON (load in chrome://tracing or Perfetto) with the metrics
snapshot (planner evidence, cache counters, histograms) merged at top level
under ``"metrics"``.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: table1,fig14..fig19,micro,accum,"
                         "accum-backends,plan-cache,serve-sparse,dist,"
                         "dist-2d,moe,lm,roofline")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="also write collected rows as JSON to PATH")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="enable repro.obs tracing and export a Chrome-trace"
                         " JSON (with metrics merged) to PATH")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    if args.trace:
        import repro.obs as obs
        obs.enable(reset=True)

    from . import paper_figures as pf
    from . import microbench as mb
    from . import roofline as rl

    suites = [
        ("table1", pf.table1),
        ("fig14", pf.fig14_performance),
        ("fig15", pf.fig15_energy),
        ("fig16", pf.fig16_utilization),
        ("fig17", pf.fig17_sparsity),
        ("fig18", pf.fig18_stddev),
        ("fig19", pf.fig19_scaling),
        ("micro", mb.spgemm_micro),
        ("kernels", mb.kernels_micro),
        ("accum", mb.sort_merge_micro),
        ("accum-backends", mb.accum_backends_micro),
        ("plan-cache", mb.plan_cache_micro),
        ("serve-sparse", mb.serve_sparse_micro),
        ("dist", mb.dist_spgemm_micro),
        ("dist-2d", mb.dist2d_micro),
        ("moe", mb.moe_dispatch_micro),
        ("lm", mb.lm_step_micro),
        ("roofline", rl.measured_rows),
    ]
    collected = []
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(f"{row[0]},{row[1]},{row[2]}", flush=True)
                collected.append({"name": row[0], "us_per_call": row[1],
                                  "derived": row[2]})
        except Exception as e:  # a failed suite must not hide the others
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr, flush=True)
            raise
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr,
              flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": collected}, f, indent=1)
        print(f"# wrote {len(collected)} rows to {args.json}",
              file=sys.stderr, flush=True)
    if args.trace:
        import repro.obs as obs
        obs.export_chrome(args.trace,
                          extra={"metrics": obs.metrics.snapshot()})
        n_ev = len(obs.get_tracer().snapshot()["events"])
        print(f"# wrote {n_ev} trace events to {args.trace}",
              file=sys.stderr, flush=True)


if __name__ == "__main__":
    main()
