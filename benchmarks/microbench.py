"""Measured (wall-clock) benchmarks of the executable JAX/Pallas pieces.

These complement the modeled paper figures with real timings of our own
implementation on this host: SPLIM SpGEMM vs scipy vs dense matmul, the
Pallas kernels in interpret mode, MoE dispatch variants, and a smoke-scale
LM train step.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, float]


def _timeit(fn, n: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6   # µs


def spgemm_micro() -> List[Row]:
    import scipy.sparse as sp
    from repro.core import (ell_cols_from_dense, ell_rows_from_dense,
                            spgemm_coo, spgemm_dense)
    rows = []
    rng = np.random.default_rng(0)
    for n, dens in [(256, 0.05), (1024, 0.01), (2048, 0.005)]:
        a_s = sp.random(n, n, dens, random_state=1, format="csr", dtype=np.float32)
        b_s = sp.random(n, n, dens, random_state=2, format="csr", dtype=np.float32)
        A = jnp.asarray(a_s.toarray())
        B = jnp.asarray(b_s.toarray())
        k = max(1, int(np.diff(a_s.tocsc().indptr).max()))
        kb = max(1, int(np.diff(b_s.indptr).max()))
        a = ell_rows_from_dense(A, k)
        b = ell_cols_from_dense(B, kb)
        f_splim = jax.jit(spgemm_dense)
        f_splim(a, b).block_until_ready()
        t_splim = _timeit(lambda: f_splim(a, b).block_until_ready())
        t_scipy = _timeit(lambda: a_s @ b_s)
        f_dense = jax.jit(lambda x, y: x @ y)
        f_dense(A, B).block_until_ready()
        t_dense = _timeit(lambda: f_dense(A, B).block_until_ready())
        rows.append((f"micro/spgemm_splim/n{n}", round(t_splim, 1),
                     round(t_dense / t_splim, 3)))
        rows.append((f"micro/spgemm_scipy/n{n}", round(t_scipy, 1), 0.0))
    return rows


def kernels_micro() -> List[Row]:
    from repro.kernels import ops
    rows = []
    rng = np.random.default_rng(1)
    ka, n, kb = 8, 2048, 8
    a_val = jnp.asarray(rng.standard_normal((ka, n)), jnp.float32)
    a_idx = jnp.asarray(rng.integers(0, n, (ka, n)), jnp.int32)
    b_val = jnp.asarray(rng.standard_normal((n, kb)), jnp.float32)
    b_idx = jnp.asarray(rng.integers(0, n, (n, kb)), jnp.int32)
    t = _timeit(lambda: jax.block_until_ready(
        ops.sccp_multiply(a_val, a_idx, b_val, b_idx)), n=3, warmup=1)
    rows.append(("micro/pallas_sccp_interp/2048", round(t, 1), ka * n * kb))
    key = jnp.asarray(rng.integers(0, 1 << 20, 4096), jnp.int32)
    val = jnp.asarray(rng.standard_normal(4096), jnp.float32)
    from repro.kernels.bitonic_merge import bitonic_merge_pallas
    t = _timeit(lambda: jax.block_until_ready(
        bitonic_merge_pallas(key, val)), n=3, warmup=1)
    rows.append(("micro/pallas_bitonic_interp/4096", round(t, 1), 4096))
    x = jnp.asarray(rng.standard_normal((n, 128)), jnp.float32)
    t = _timeit(lambda: jax.block_until_ready(
        ops.ell_spmm(a_val, a_idx, x, 1024)), n=3, warmup=1)
    rows.append(("micro/pallas_ellspmm_interp/2048x128", round(t, 1), 0.0))
    return rows


def sort_merge_micro() -> List[Row]:
    """Accumulation engines head-to-head on one product stream: the global
    ``jax.lax.sort`` path (core/accumulate.accumulate) vs the tiled bitonic
    merge tree (kernels/ops.sort_merge). Streams are 2^16 and 2^18 products
    over a 64×64 coordinate space — the multi-tile regime the tree exists
    for. ``derived`` column = speedup of the tree over the global sort
    (off-TPU the kernels run in interpret mode, where XLA's fused sort wins;
    the tree's point is VMEM-resident blocking on real TPU)."""
    from repro.core.accumulate import accumulate
    from repro.kernels import ops
    rows = []
    rng = np.random.default_rng(2)
    n_rows = n_cols = 64
    for logn in (16, 18):
        n = 1 << logn
        row = jnp.asarray(rng.integers(0, n_rows, n), jnp.int32)
        col = jnp.asarray(rng.integers(0, n_cols, n), jnp.int32)
        val = jnp.asarray(rng.standard_normal(n), jnp.float32)
        out_cap = n_rows * n_cols

        f_sort = jax.jit(lambda r, c, v: accumulate(r, c, v, out_cap,
                                                    n_rows, n_cols))
        jax.block_until_ready(f_sort(row, col, val))
        t_sort = _timeit(lambda: jax.block_until_ready(
            f_sort(row, col, val)), n=3, warmup=1)

        f_tree = jax.jit(lambda r, c, v: ops.sort_merge(r, c, v, n_rows,
                                                        n_cols, tile=4096))
        jax.block_until_ready(f_tree(row, col, val))
        t_tree = _timeit(lambda: jax.block_until_ready(
            f_tree(row, col, val)), n=3, warmup=1)

        rows.append((f"micro/accum_global_sort/2^{logn}", round(t_sort, 1), 0.0))
        rows.append((f"micro/accum_merge_tree/2^{logn}", round(t_tree, 1),
                     round(t_sort / t_tree, 3)))

        # streaming engine over the same (already materialized) stream:
        # chunk-scan compact→merge, sort working set one 4096-lane tile
        from repro.core import accumulate_stream
        f_stream = jax.jit(lambda r, c, v: accumulate_stream(
            r, c, v, out_cap, n_rows, n_cols, backend="stream").val)
        jax.block_until_ready(f_stream(row, col, val))
        t_stream = _timeit(lambda: jax.block_until_ready(
            f_stream(row, col, val)), n=3, warmup=1)
        rows.append((f"micro/accum_stream_flat/2^{logn}", round(t_stream, 1),
                     round(t_sort / t_stream, 3)))
    return rows


def accum_backends_micro() -> List[Row]:
    """All six accumulation backends head-to-head on planner-relevant
    shapes, plus a validation row per shape: did the planner's choice land
    within 2× of the best measured backend?

    Shapes span the regimes the backends are built for: a sparse mid-size
    SpGEMM (sort's home turf off-TPU), a duplication-heavy small coordinate
    space (hash's and search's), a DENSE duplicate-dominated stream
    (``n48_dup_heavy`` — the paper's alignment-beats-resorting case the
    'search' backend exists for), a skewed row distribution (bucket's), and
    a padding-heavy ELLPACK (oversized k, mostly INVALID lanes) where the
    streaming engine's per-tile compaction pays off. ``derived`` column =
    speedup vs the 'sort' baseline for backend rows, and
    best_time/chosen_time (≥ 0.5 passes the 2× criterion) for 'planner'
    rows. Tiny shapes on purpose — this doubles as the CI smoke suite
    feeding BENCH_accum.json.

    Dup-heavy shapes additionally log a ``search_alignment_win`` evidence
    row (us = measured 'search' time, derived = t_sort/t_search) so the
    BENCH file records whether in-situ alignment beat the full re-sort on
    the host that produced it — the paper's prediction, checkable per run.

    Per shape two memory-evidence rows make the compaction win visible:
    ``stream_density`` (us column = valid SCCP products, derived =
    valid / k_a·n·k_b lane density — how much of the materialized stream is
    ELLPACK-padding dead weight) and ``interm_bytes_{sort,stream}`` (the
    planner's modeled peak materialized-intermediate bytes; the stream
    row's derived = sort_bytes / stream_bytes reduction factor).
    """
    import dataclasses
    from functools import partial
    import repro.obs as obs
    from repro.core import (ell_cols_from_dense, ell_rows_from_dense,
                            spgemm_coo)
    from repro.core.sccp import count_products
    from repro.plan import make_plan
    rows: List[Row] = []
    rng = np.random.default_rng(7)
    shapes = [                              # tag, n, density, skew, k_force
        ("n128_sparse", 128, 0.05, 0.0, None),
        ("n64_dup", 64, 0.25, 0.0, None),
        # half-dense 48×48: the product stream carries ~20× duplicates per
        # unique coordinate — alignment against nnz(C) keys vs re-sorting
        # the whole stream is exactly the paper's in-situ-search bet
        ("n48_dup_heavy", 48, 0.5, 0.0, None),
        ("n96_skew", 96, 0.05, 0.5, None),
        ("n64_pad", 64, 0.04, 0.0, 16),     # k ≫ nnz: dead-lane dominated
        # k_a·n·k_b = 2^18 lanes at ~1% valid density: the regime the
        # streaming engine exists for (intermediate-bound, tiny nnz(C))
        ("n256_pad", 256, 0.008, 0.0, 32),
    ]
    for tag, n, dens, skew, k_force in shapes:
        a = ((rng.random((n, n)) < dens)
             * rng.standard_normal((n, n))).astype(np.float32)
        b = ((rng.random((n, n)) < dens)
             * rng.standard_normal((n, n))).astype(np.float32)
        if skew:
            hot = rng.choice(n, n // 8, replace=False)
            a[hot] = (rng.standard_normal((len(hot), n))
                      * (rng.random((len(hot), n)) < skew)).astype(np.float32)
        ka = k_force or max(1, int((a != 0).sum(0).max()))
        kb = k_force or max(1, int((b != 0).sum(1).max()))
        ea = ell_rows_from_dense(jnp.asarray(a), ka)
        eb = ell_cols_from_dense(jnp.asarray(b), kb)
        plan = make_plan(ea, eb)
        lanes = ka * n * kb
        valid = int(count_products(ea, eb))
        rows.append((f"micro/stream_density/{tag}", float(valid),
                     round(valid / lanes, 4)))
        i_sort, i_stream = plan.est["interm_sort"], plan.est["interm_stream"]
        rows.append((f"micro/interm_bytes_sort/{tag}", round(i_sort, 1), 1.0))
        rows.append((f"micro/interm_bytes_stream/{tag}", round(i_stream, 1),
                     round(i_sort / i_stream, 2)))
        if obs.is_enabled():
            from repro.core.spgemm import spgemm_coo_numeric
            from repro.plan import make_structure
            structure = make_structure(ea, eb, plan=plan)
        times = {}
        for backend in ("sort", "tiled", "bucket", "hash", "stream",
                        "search"):
            p = dataclasses.replace(plan, backend=backend)
            f = jax.jit(partial(spgemm_coo, out_cap=plan.out_cap,
                                accumulator=backend, plan=p))
            jax.block_until_ready(f(ea, eb).val)
            times[backend] = _timeit(
                lambda: jax.block_until_ready(f(ea, eb).val), n=3, warmup=1)
            rows.append((f"micro/accum_{backend}/{tag}",
                         round(times[backend], 1),
                         round(times["sort"] / times[backend], 3)))
            if obs.is_enabled():
                # one eager (unjitted) pass per backend so the trace carries
                # real per-phase spans with device syncs — multiply +
                # accumulate (feeding the est-vs-measured ledger) and the
                # numeric phase against the shared structure
                jax.block_until_ready(spgemm_coo(
                    ea, eb, out_cap=plan.out_cap, accumulator=backend,
                    plan=p).val)
                st = dataclasses.replace(structure, plan=p)
                jax.block_until_ready(spgemm_coo_numeric(
                    ea, eb, st, validate=False).val)
        if "dup" in tag:
            # evidence row (outside the accum_ regression regex): did the
            # paper's alignment beat the full re-sort on this host?
            rows.append((f"micro/search_alignment_win/{tag}",
                         round(times["search"], 1),
                         round(times["sort"] / times["search"], 3)))
        best = min(times.values())
        rows.append((f"micro/accum_planner_{plan.backend}/{tag}",
                     round(times[plan.backend], 1),
                     round(best / times[plan.backend], 3)))
    return rows


def plan_cache_micro() -> List[Row]:
    """Two-phase SpGEMM: what the fingerprint-keyed structure cache buys.

    Per shape three rows:
      * ``micro/plan_cache_cold/<tag>`` — the one-phase call as an uncached
        user pays it: host-side planning (exact symbolic pass) + coordinate
        sort + accumulation, every call.
      * ``micro/plan_cache_warm/<tag>`` — the realistic warm call: a
        ``StructureCache.get`` (fingerprint hash + LRU hit) followed by
        ``spgemm_coo_numeric`` (scatter into the precomputed structure, no
        planning, no sort). ``derived`` = cold/warm speedup — the CI gate
        asserts ≥ 1.5×.
      * ``micro/plan_cache_hitrate/<tag>`` — evidence row: 16 calls cycling
        4 sparsity patterns through one cache; ``us_per_call`` is the
        amortized per-call time (4 symbolic builds + 12 numeric-only) and
        ``derived`` the measured hit rate (0.75 by construction).
    """
    from repro.core import (ell_cols_from_dense, ell_rows_from_dense,
                            spgemm_coo)
    from repro.core.spgemm import spgemm_coo_numeric
    from repro.plan import StructureCache
    rows: List[Row] = []
    rng = np.random.default_rng(13)
    for tag, n, dens in [("n128", 128, 0.05), ("n256", 256, 0.02)]:
        def mk_a():
            ad = ((rng.random((n, n)) < dens)
                  * rng.standard_normal((n, n))).astype(np.float32)
            ka = max(1, int((ad != 0).sum(0).max()))
            return ell_rows_from_dense(jnp.asarray(ad), ka)
        bd = ((rng.random((n, n)) < dens)
              * rng.standard_normal((n, n))).astype(np.float32)
        kb = max(1, int((bd != 0).sum(1).max()))
        b = ell_cols_from_dense(jnp.asarray(bd), kb)
        a = mk_a()

        t_cold = _timeit(lambda: jax.block_until_ready(
            spgemm_coo(a, b).val), n=5, warmup=2)

        cache = StructureCache(capacity=8)
        cache.get(a, b)                       # symbolic phase paid once here

        def warm():
            st = cache.get(a, b)              # fingerprint hash + LRU hit
            jax.block_until_ready(spgemm_coo_numeric(
                a, b, st, validate=False).val)
        t_warm = _timeit(warm, n=5, warmup=2)
        rows.append((f"micro/plan_cache_cold/{tag}", round(t_cold, 1), 1.0))
        rows.append((f"micro/plan_cache_warm/{tag}", round(t_warm, 1),
                     round(t_cold / t_warm, 3)))

        pats = [a] + [mk_a() for _ in range(3)]
        mixed = StructureCache(capacity=8)
        for p in pats:                        # trace/compile outside timing
            jax.block_until_ready(spgemm_coo_numeric(
                p, b, mixed.get(p, b), validate=False).val)
        mixed.clear()
        t0 = time.perf_counter()
        calls = 16
        for i in range(calls):
            p = pats[i % len(pats)]
            jax.block_until_ready(spgemm_coo_numeric(
                p, b, mixed.get(p, b), validate=False).val)
        us = (time.perf_counter() - t0) / calls * 1e6
        s = mixed.stats()
        rows.append((f"micro/plan_cache_hitrate/{tag}", round(us, 1),
                     round(s["hits"] / (s["hits"] + s["misses"]), 3)))
    return rows


def serve_sparse_micro() -> List[Row]:
    """Sparse-serving suite (the PR-9 acceptance benchmark).

    Per shape tag, a decode-shaped SpMM ``y = x @ W`` with a 2:4-style
    magnitude-pruned weight, three execution paths on identical math:

      * ``micro/serve_sparse_dense/<tag>`` — pruned-but-dense matmul
        baseline (the in-file normalizer for the regression gate);
      * ``micro/serve_sparse_ell/<tag>`` — general column-wise ELLPACK
        (``sparse_linear_apply``, gather/segment-sum);
      * ``micro/serve_sparse_nm/<tag>`` — the gather-free N:M condensed
        path (``nm_spmm``: M masked matmuls on R = d_in·N/M rows).

    ``derived`` on those rows = requests/s at the measured latency (T
    activation rows per call). Two extra rows:

      * ``micro/nm_vs_ell_win/<tag>`` — ``us`` is the N:M time, ``derived``
        the ELLPACK/N:M speedup; CI requires ≥ 1 on at least one 2:4 tag.
      * ``micro/serve_sparse_batched/<tag>`` — one engine
        ``SparseGemmBatcher`` flush of 4 heterogeneous-pattern requests
        through ``spgemm_coo_numeric_batched`` slots; ``derived`` = the
        4-sequential-numeric-calls time over the batched flush time.
    """
    from repro.core.formats import ell_cols_from_dense, ell_rows_from_dense
    from repro.core.spgemm import spgemm_coo_numeric
    from repro.models.sparse import (ell_from_pruned, magnitude_prune_nm,
                                     nm_linear_apply, sparse_linear_apply)
    from repro.core.nm import nm_from_dense
    from repro.plan import StructureCache
    from repro.serve import SparseGemmBatcher
    rows: List[Row] = []
    rng = np.random.default_rng(17)
    for tag, t_rows, d_in, d_out, (nn, mm) in [
            ("t64_d256_2to4", 64, 256, 256, (2, 4)),
            ("t32_d128_2to4", 32, 128, 128, (2, 4))]:
        w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
        wp = magnitude_prune_nm(w, nn, mm)
        x = jnp.asarray(rng.standard_normal((t_rows, d_in)), jnp.float32)
        w_ell = ell_from_pruned(wp)
        w_nm = nm_from_dense(wp, nn, mm)

        f_dense = jax.jit(lambda xx, ww: xx @ ww)
        jax.block_until_ready(f_dense(x, wp))
        t_dense = _timeit(lambda: jax.block_until_ready(f_dense(x, wp)))
        f_ell = jax.jit(sparse_linear_apply)
        jax.block_until_ready(f_ell(x, w_ell))
        t_ell = _timeit(lambda: jax.block_until_ready(f_ell(x, w_ell)))
        f_nm = jax.jit(nm_linear_apply)
        jax.block_until_ready(f_nm(x, w_nm))
        t_nm = _timeit(lambda: jax.block_until_ready(f_nm(x, w_nm)))
        for variant, t in (("dense", t_dense), ("ell", t_ell), ("nm", t_nm)):
            rows.append((f"micro/serve_sparse_{variant}/{tag}", round(t, 1),
                         round(t_rows / (t / 1e6), 1)))
        rows.append((f"micro/nm_vs_ell_win/{tag}", round(t_nm, 1),
                     round(t_ell / t_nm, 3)))

    # engine-style slot batching: 4 same-shape, different-pattern SpGEMMs
    tag = "n96x4"
    n = 96
    def mk_pair(seed):
        r = np.random.default_rng(seed)
        ad = ((r.random((n, n)) < 0.04)
              * r.standard_normal((n, n))).astype(np.float32)
        bd = ((r.random((n, n)) < 0.04)
              * r.standard_normal((n, n))).astype(np.float32)
        ka = max(1, int((ad != 0).sum(0).max()))
        kb = max(1, int((bd != 0).sum(1).max()))
        # shared slab counts so the batcher groups all four into one wave
        return (ell_rows_from_dense(jnp.asarray(ad), max(ka, 8)),
                ell_cols_from_dense(jnp.asarray(bd), max(kb, 8)))
    pairs = [mk_pair(s) for s in range(4)]
    cache = StructureCache(capacity=16)
    bt = SparseGemmBatcher(cache, max_slots=4)
    for a, b in pairs:                       # symbolic + compile outside timing
        bt.submit(a, b)
    bt.flush()
    sts = [cache.get(a, b) for a, b in pairs]
    for (a, b), st in zip(pairs, sts):
        jax.block_until_ready(spgemm_coo_numeric(a, b, st, validate=False).val)

    def seq():
        for (a, b), st in zip(pairs, sts):
            jax.block_until_ready(
                spgemm_coo_numeric(a, b, st, validate=False).val)
    t_seq = _timeit(seq, n=5, warmup=1)

    def batched():
        for a, b in pairs:
            bt.submit(a, b)
        bt.flush()
    t_batch = _timeit(batched, n=5, warmup=1)
    # 'seq' is the in-file normalizer for this group (no dense variant of a
    # 4-request SpGEMM wave exists); derived on 'batched' = the wave speedup
    rows.append((f"micro/serve_sparse_seq/{tag}", round(t_seq, 1), 1.0))
    rows.append((f"micro/serve_sparse_batched/{tag}", round(t_batch, 1),
                 round(t_seq / t_batch, 3)))
    return rows


def moe_dispatch_micro() -> List[Row]:
    """ELLPACK one-hot dispatch vs SPLIM sort dispatch (measured FLOP proxy
    via wall-time on CPU; dry-run flops recorded in §Perf)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import build_model
    rows = []
    base = get_config("granite-moe-3b-a800m").reduced()
    toks = jax.random.randint(jax.random.PRNGKey(0), (4, 64), 0, base.vocab)
    for disp in ("ellpack", "sort"):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, dispatch=disp))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        f = jax.jit(lambda p, t: m.loss(p, {"tokens": t}))
        f(params, toks).block_until_ready()
        t = _timeit(lambda: f(params, toks).block_until_ready(), n=5)
        rows.append((f"micro/moe_dispatch_{disp}", round(t, 1), 0.0))
    return rows


def lm_step_micro() -> List[Row]:
    from repro.configs import get_config
    from repro.launch.steps import make_train_step
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init
    rows = []
    for arch in ("qwen2-0.5b", "granite-moe-3b-a800m", "falcon-mamba-7b"):
        cfg = get_config(arch).reduced()
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(m, AdamWConfig()), donate_argnums=(0, 1))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64),
                                              0, cfg.vocab)}
        params, opt, metrics = step(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        t0 = time.perf_counter()
        N = 3
        for _ in range(N):
            params, opt, metrics = step(params, opt, batch)
        jax.block_until_ready(metrics["loss"])
        us = (time.perf_counter() - t0) / N * 1e6
        toks_s = 4 * 64 / (us / 1e6)
        rows.append((f"micro/train_step/{arch}-smoke", round(us, 1),
                     round(toks_s, 0)))
    return rows


def dist_spgemm_micro() -> List[Row]:
    """Distributed SpGEMM: sparse-native ``spgemm_coo_sharded`` (both
    schedules) against the dense-psum ``ring_spgemm`` baseline.

    Meaningful with several devices — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI
    ``tests-multidevice`` job does; a 1-device run degenerates to a 1-ring).
    ``derived`` = modeled per-device peak partial-result bytes of the dense
    baseline over the sparse path: the dense path scatters into a full
    n_rows×n_cols accumulator per device, the sparse path's partials are the
    device-local product stream (~stream/n_dev) plus its COO capacities, so
    the ratio growing with the mesh is exactly the paper's "intermediate
    results never cross arrays" scaling claim made measurable.
    """
    import dataclasses
    from repro.core import ell_cols_from_dense, ell_rows_from_dense
    from repro.core.distributed import (pad_slabs_a, pad_slabs_b, ring_spgemm,
                                        spgemm_coo_sharded)
    from repro.plan import make_dist_plan
    rows: List[Row] = []
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("ring",))
    rng = np.random.default_rng(11)
    for tag, n, dens in [("n256", 256, 0.02), ("n512", 512, 0.005)]:
        A = ((rng.random((n, n)) < dens)
             * rng.standard_normal((n, n))).astype(np.float32)
        B = ((rng.random((n, n)) < dens)
             * rng.standard_normal((n, n))).astype(np.float32)
        ka = max(1, int((A != 0).sum(0).max()))
        kb = max(1, int((B != 0).sum(1).max()))
        a = ell_rows_from_dense(jnp.asarray(A), ka)
        b = ell_cols_from_dense(jnp.asarray(B), kb)
        dense_bytes = 4 * n * n                      # per-device dense partial C
        f_dense = jax.jit(lambda av, bv: ring_spgemm(av, bv, mesh, "ring"))
        jax.block_until_ready(f_dense(a, b))
        t = _timeit(lambda: jax.block_until_ready(f_dense(a, b)), n=3, warmup=1)
        rows.append((f"micro/dist_densepsum/{tag}_dev{n_dev}", round(t, 1), 1.0))
        dp = make_dist_plan(a, b, n_dev=n_dev)
        ap, bp = pad_slabs_a(a, n_dev), pad_slabs_b(b, n_dev)
        stream_loc = ap.k * n * bp.k // n_dev        # device-local product lanes
        for sched in ("ring", "cstat"):
            dps = dataclasses.replace(dp, schedule=sched)
            f = jax.jit(lambda av, bv: spgemm_coo_sharded(
                av, bv, mesh, "ring", dist_plan=dps).val)
            jax.block_until_ready(f(a, b))
            t = _timeit(lambda: jax.block_until_ready(f(a, b)), n=3, warmup=1)
            caps = (dp.local_cap + n_dev * dp.bin_cap if sched == "ring"
                    else 0) + dp.block_cap
            sparse_bytes = 12 * (stream_loc + caps)  # val+row+col per lane
            rows.append((f"micro/dist_sparse_{sched}/{tag}_dev{n_dev}",
                         round(t, 1), round(dense_bytes / sparse_bytes, 3)))
    return rows


def dist2d_micro() -> List[Row]:
    """Communication-avoiding 2D schedule evidence (``--only dist-2d``).

    Two row groups, both registered with ``check_regression`` (unknown
    ``dist2d_*`` names are a hard failure there):

      * ``dist2d_comm_bytes_{ring,cstat,summa}/<tag>_devN`` — the DistPlan's
        modeled **per-device comm bytes** at N ∈ {2, 4, 8} (the value column
        carries bytes, not µs — evidence rows, ignored by the timing gate).
        ``derived`` = bytes / same-mesh ring bytes. The 1D schedules rotate
        all of B (or replicate all of A) through every device no matter the
        mesh size, so their per-device volume stays ~flat-to-growing; the 2D
        grid moves ``(pc−1)/p`` of A + ``(pr−1)/p`` of B, shrinking ~1/√p —
        summa's derived falling below 1.0 as N grows is the paper-adjacent
        communication-avoiding claim made measurable. CI gates fresh-run
        summa ≤ ring at 8 devices. At N=2 there is no pr,pc ≥ 2
        factorization, so summa is modeled (and gated) as exactly ring.
      * ``dist2d_overlap_{on,off}/<tag>_devN`` — wall-clock of the summa
        schedule with/without double-buffered prefetch (``derived`` on the
        'on' row = off/on speedup). Fake host devices make the ppermute a
        memcpy, so ≈1 here; async-ICI hardware is where the prefetch pays.
    """
    import dataclasses
    from jax.sharding import Mesh
    from repro.core import ell_cols_from_dense, ell_rows_from_dense
    from repro.core.distributed import spgemm_coo_sharded
    from repro.plan import make_dist_plan
    rows: List[Row] = []
    devs = jax.devices()
    rng = np.random.default_rng(13)
    n, dens, tag = 256, 0.02, "n256"
    A = ((rng.random((n, n)) < dens)
         * rng.standard_normal((n, n))).astype(np.float32)
    B = ((rng.random((n, n)) < dens)
         * rng.standard_normal((n, n))).astype(np.float32)
    ka = max(1, int((A != 0).sum(0).max()))
    kb = max(1, int((B != 0).sum(1).max()))
    a = ell_rows_from_dense(jnp.asarray(A), ka)
    b = ell_cols_from_dense(jnp.asarray(B), kb)
    for nd in (2, 4, 8):
        if nd > len(devs):
            continue
        dp = make_dist_plan(a, b, n_dev=nd)
        ring_b = dp.est["ring_comm_bytes"]
        for sched in ("ring", "cstat", "summa"):
            v = dp.est[f"{sched}_comm_bytes"]
            rows.append((f"micro/dist2d_comm_bytes_{sched}/{tag}_dev{nd}",
                         round(v, 1), round(v / max(ring_b, 1.0), 3)))
    nd = max(d for d in (2, 4, 8) if d <= len(devs))
    mesh = Mesh(np.array(devs[:nd]), ("ring",))
    dps = dataclasses.replace(make_dist_plan(a, b, n_dev=nd),
                              schedule="summa")
    ts = {}
    for ov in (True, False):
        f = jax.jit(lambda av, bv, _ov=ov: spgemm_coo_sharded(
            av, bv, mesh, "ring", dist_plan=dps, overlap=_ov).val)
        jax.block_until_ready(f(a, b))
        ts[ov] = _timeit(lambda: jax.block_until_ready(f(a, b)),
                         n=3, warmup=1)
    rows.append((f"micro/dist2d_overlap_off/{tag}_dev{nd}",
                 round(ts[False], 1), 1.0))
    rows.append((f"micro/dist2d_overlap_on/{tag}_dev{nd}",
                 round(ts[True], 1),
                 round(ts[False] / max(ts[True], 1e-9), 3)))
    return rows
