"""Bench-regression gate: fail when an accumulation-backend row regresses
more than ``--threshold``× against the committed baseline.

Usage: python benchmarks/check_regression.py BENCH_accum.json fresh.json
                                             [--threshold 1.5] [--absolute]

By default each backend's time is first normalized to the ``sort`` row of
the same shape in the *same* file, and the gate compares those normalized
ratios — this makes the check robust to absolute machine-speed differences
between the host that produced the committed baseline and the CI runner.
Two blind spots come with that: a regression that slows every backend
uniformly, and one that slows only ``sort`` itself (its self-ratio is
identically 1 and it *loosens* the other rows' ratios). Both are covered
by a generous raw-time backstop — any row slower than ``--max-absolute``×
its baseline time fails regardless of normalization (default 10×, wide
enough for runner-speed variance, tight enough to catch either blind
spot); the planner within-2× gate and the uploaded artifacts cover finer
trend-watching. ``--absolute`` compares raw ``us_per_call`` at the main
threshold instead, which is only meaningful on the same machine.

Planner rows (``accum_planner_*``) duplicate a backend row and are skipped,
as are the memory-evidence rows (``stream_density``/``interm_bytes_*``/
``plan_cache_hitrate`` — modeled constants or rates, not timings);
a backend/shape present in the baseline but missing from the fresh run is a
hard failure (silently dropping a row must not pass the gate).

Any other row name is an **evidence row** (``roofline_*``, future suites)
and is ignored by this gate by construction: only names matching the two
timing-row regexes below participate, so adding new evidence rows to
BENCH_accum.json can never break the regression check. The count of
ignored rows is printed for visibility. The one exception cuts the other
way: an ``accum_<backend>`` row whose backend is NOT in ``_KNOWN_BACKENDS``
is a hard failure — a newly added backend must be registered with this gate
(and land in the committed baseline) rather than silently skipping it.

``plan_cache_{cold,warm}`` rows (the structure-cache suite) ride the same
normalized comparison with ``cold`` as the in-file normalizer, plus one
extra machine-independent gate on the fresh run alone: warm must beat cold
by at least ``--min-cache-speedup`` (default 1.5×) — the two-phase split's
reason to exist, asserted on every push.

``serve_sparse_{dense,ell,nm,batched}`` rows (the serving suite) likewise
ride the normalized comparison with ``dense`` as the in-file normalizer
(unknown serve_sparse variants are a hard failure, same as unknown
backends). ``micro/nm_vs_ell_win`` rows carry the measured ELLPACK/N:M
speedup in ``derived`` and feed one more fresh-run-only gate: at least one
2:4-style tag must show a win ≥ ``--min-nm-win`` (default 1.0) — the N:M
fast path's reason to exist. Any other ``micro/nm_*`` row name is a hard
failure until registered here.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

_ROW = re.compile(r"micro/accum_([a-z0-9_]+)/(.+)")
# Every backend the gate knows how to judge. An accum_<backend> row outside
# this set is a HARD FAILURE, not a skip — a new backend must be added here
# (and to the committed baseline) so it can never dodge the gate. Planner
# rows (accum_planner_<backend>) duplicate a backend row and stay skipped.
_KNOWN_BACKENDS = {"sort", "tiled", "bucket", "hash", "stream", "search"}
# plan-cache suite rows ride the same gate; 'cold' plays the role 'sort'
# plays for the backend rows — the in-file normalizer
_CACHE_ROW = re.compile(r"micro/plan_cache_(cold|warm)/(.+)")
# serving suite rows (benchmarks.run --only serve-sparse): 'dense' is the
# in-file normalizer. Same hard-failure contract as the backends: a
# serve_sparse_<variant> row outside this set must be registered here.
_SERVE_ROW = re.compile(r"micro/serve_sparse_([a-z0-9_]+)/(.+)")
_KNOWN_SERVE_VARIANTS = {"dense", "ell", "nm", "seq", "batched"}
# N:M evidence rows: 'derived' is the measured ELLPACK/N:M speedup, gated
# on the fresh run alone by --min-nm-win. Any other micro/nm_* row name is
# a hard failure — new N:M rows must be registered with this gate.
_NM_ROW = re.compile(r"micro/nm_(vs_ell_win)/(.+)")
_NM_ANY = re.compile(r"micro/nm_[a-z0-9_]+/.+")
# dist-2d suite rows (benchmarks.run --only dist-2d): comm_bytes_* rows
# carry modeled per-device bytes (value column is bytes, not µs) and
# overlap_{on,off} rows carry wall-clock — all evidence rows, excluded from
# the timing comparison by construction, but an unregistered dist2d_*
# variant is a hard failure like everywhere else. CI separately gates
# fresh-run summa comm bytes ≤ ring's at 8 devices from these rows.
_DIST2D_ROW = re.compile(r"micro/dist2d_([a-z0-9_]+)/(.+)")
_KNOWN_DIST2D_VARIANTS = {"comm_bytes_ring", "comm_bytes_cstat",
                          "comm_bytes_summa", "overlap_on", "overlap_off"}


def _norm_key(family: str) -> str:
    return {"plan_cache": "cold", "serve_sparse": "dense"}.get(family, "sort")


def _backend_times(path: str) -> tuple:
    """``({(family, shape_tag): {backend: us_per_call}}, {tag: nm_win})``
    from a benchmarks.run --json dump. ``family`` is 'accum' (backend rows,
    sort-normalized), 'plan_cache' (cold/warm rows, cold-normalized) or
    'serve_sparse' (serving variants, dense-normalized); the second dict
    holds the ``micro/nm_vs_ell_win`` evidence rows' ``derived`` speedups.
    Every other row name — planner/evidence/roofline rows, and any row
    name a future suite introduces — is deliberately ignored."""
    out: dict = {}
    nm_wins: dict = {}
    ignored = 0
    unknown = []
    for r in json.load(open(path))["rows"]:
        nm = _NM_ROW.fullmatch(r["name"])
        if nm:
            nm_wins[nm.group(2)] = float(r["derived"])
            continue
        if _NM_ANY.fullmatch(r["name"]):
            unknown.append(r["name"])        # unregistered micro/nm_* row
            continue
        d2 = _DIST2D_ROW.fullmatch(r["name"])
        if d2:
            if d2.group(1) in _KNOWN_DIST2D_VARIANTS:
                ignored += 1                 # evidence row, not a timing row
            else:
                unknown.append(r["name"])    # unregistered dist2d_* row
            continue
        m = _ROW.fullmatch(r["name"])
        fam = "accum"
        if not m:
            m = _CACHE_ROW.fullmatch(r["name"])
            fam = "plan_cache"
        if not m:
            m = _SERVE_ROW.fullmatch(r["name"])
            fam = "serve_sparse"
        if m:
            backend, tag = m.groups()
            if fam == "accum" and backend.startswith("planner_"):
                ignored += 1                 # duplicates a backend row
                continue
            if fam == "accum" and backend not in _KNOWN_BACKENDS:
                unknown.append(r["name"])
                continue
            if fam == "serve_sparse" and backend not in _KNOWN_SERVE_VARIANTS:
                unknown.append(r["name"])
                continue
            out.setdefault((fam, tag), {})[backend] = float(r["us_per_call"])
        else:
            ignored += 1
    if unknown:
        raise SystemExit(
            f"{path}: rows unknown to this gate: {sorted(unknown)} — add "
            "them to _KNOWN_BACKENDS / _KNOWN_SERVE_VARIANTS / _NM_ROW / "
            "_KNOWN_DIST2D_VARIANTS (and the committed baseline) so new "
            "rows cannot dodge the check")
    if ignored:
        print(f"# {path}: {ignored} evidence row(s) ignored by the gate")
    return out, nm_wins


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly measured JSON")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed slowdown factor per row (default 1.5)")
    ap.add_argument("--absolute", action="store_true",
                    help="compare raw us_per_call (same-machine only) "
                         "instead of sort-normalized ratios")
    ap.add_argument("--max-absolute", type=float, default=10.0,
                    help="raw-time backstop multiplier applied to every row "
                         "in normalized mode (default 10)")
    ap.add_argument("--min-cache-speedup", type=float, default=1.5,
                    help="min required cold/warm speedup for plan_cache rows "
                         "in the FRESH run (default 1.5; 0 disables)")
    ap.add_argument("--min-nm-win", type=float, default=1.0,
                    help="at least one fresh nm_vs_ell_win row must show an "
                         "ELLPACK/N:M speedup ≥ this (default 1.0; 0 "
                         "disables; skipped when no such rows were run)")
    args = ap.parse_args()

    base, _ = _backend_times(args.baseline)
    fresh, fresh_nm = _backend_times(args.fresh)
    if not any(fam == "accum" for fam, _ in base):
        print(f"no accum backend rows in {args.baseline}", file=sys.stderr)
        return 1
    failures = []
    for (fam, tag), backends in sorted(base.items()):
        norm = _norm_key(fam)
        if fam == "serve_sparse" and norm not in backends:
            norm = "seq"      # batched-wave group: sequential-path normalizer
        if not args.absolute and norm not in backends:
            failures.append(f"{tag}: no {norm} row in baseline to normalize by")
            continue
        if not args.absolute and norm not in fresh.get((fam, tag), {}):
            failures.append(
                f"{tag}: no {norm} row in fresh run to normalize by")
            continue
        for backend, t_base in sorted(backends.items()):
            label = f"{fam}_{backend}/{tag}"
            t_fresh = fresh.get((fam, tag), {}).get(backend)
            if t_fresh is None:
                failures.append(f"{label}: missing from fresh run")
                continue
            raw = t_fresh / t_base
            if args.absolute:
                ratio = raw
            else:
                ratio = ((t_fresh / fresh[(fam, tag)][norm])
                         / (t_base / backends[norm]))
            bad = ratio > args.threshold
            if not args.absolute and raw > args.max_absolute:
                bad = True
                failures.append(f"{label}: raw x{raw:.2f} > "
                                f"x{args.max_absolute} backstop")
            print(f"{'FAIL' if bad else 'ok'}: {label} "
                  f"x{ratio:.2f} (base {t_base:.0f}us, fresh {t_fresh:.0f}us)")
            if ratio > args.threshold:
                failures.append(
                    f"{label}: x{ratio:.2f} > x{args.threshold}")
    # structure-cache win gate: the fresh run's warm (numeric-only) path must
    # actually beat its own cold (plan+sort) path — machine-independent by
    # construction, so it reads the fresh file only
    if args.min_cache_speedup > 0:
        for (fam, tag), backends in sorted(fresh.items()):
            if fam != "plan_cache" or not {"cold", "warm"} <= set(backends):
                continue
            sp = backends["cold"] / backends["warm"]
            ok = sp >= args.min_cache_speedup
            print(f"{'ok' if ok else 'FAIL'}: plan_cache/{tag} warm speedup "
                  f"x{sp:.2f} (need ≥ x{args.min_cache_speedup})")
            if not ok:
                failures.append(f"plan_cache/{tag}: warm only x{sp:.2f} over "
                                f"cold, need x{args.min_cache_speedup}")
    # N:M fast-path win gate: the fresh run must show the gather-free N:M
    # kernel beating general ELLPACK on at least one 2:4-style suite —
    # machine-independent (an in-run ratio), fresh file only, skipped
    # entirely when the serve-sparse suite wasn't part of the run
    if args.min_nm_win > 0 and fresh_nm:
        best_tag = max(fresh_nm, key=fresh_nm.get)
        best = fresh_nm[best_tag]
        ok = best >= args.min_nm_win
        for tag, win in sorted(fresh_nm.items()):
            print(f"# nm_vs_ell_win/{tag}: x{win:.2f}")
        print(f"{'ok' if ok else 'FAIL'}: best N:M-vs-ELLPACK win "
              f"x{best:.2f} ({best_tag}, need ≥ x{args.min_nm_win})")
        if not ok:
            failures.append(f"nm_vs_ell_win: best x{best:.2f} < "
                            f"x{args.min_nm_win} ({best_tag})")
    if failures:
        print(f"\n{len(failures)} regression(s) vs {args.baseline}:",
              file=sys.stderr)
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"all rows within x{args.threshold} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
