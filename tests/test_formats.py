"""Format round-trips + hybrid splitting, incl. hypothesis property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import (coo_from_dense, ell_cols_from_dense,
                        ell_rows_from_dense)
from repro.core.hybrid import (ell_width_rule, hybrid_spgemm_dense,
                               split_cols_hybrid, split_rows_hybrid)

from conftest import random_sparse


def test_ell_rows_roundtrip(rng):
    a = random_sparse(rng, 40, 30, 0.2)
    k = int((a != 0).sum(0).max())
    ell = ell_rows_from_dense(jnp.array(a), k)
    np.testing.assert_allclose(np.asarray(ell.to_dense()), a, atol=1e-6)


def test_ell_cols_roundtrip(rng):
    b = random_sparse(rng, 25, 45, 0.2)
    k = int((b != 0).sum(1).max())
    ell = ell_cols_from_dense(jnp.array(b), k)
    np.testing.assert_allclose(np.asarray(ell.to_dense()), b, atol=1e-6)


def test_coo_roundtrip(rng):
    a = random_sparse(rng, 17, 23, 0.15)
    coo = coo_from_dense(jnp.array(a), cap=17 * 23)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a, atol=1e-6)
    assert int(coo.nnz()) == int((a != 0).sum())


def test_ell_truncation_drops_overflow(rng):
    """k smaller than max column nnz silently truncates (documented)."""
    a = np.zeros((8, 4), np.float32)
    a[:, 1] = 1.0                       # column with 8 nnz
    ell = ell_rows_from_dense(jnp.array(a), 3)
    assert float(ell.to_dense().sum()) == 3.0


def test_condense_order_preserved(rng):
    """ELLPACK keeps original row order within a column (stable condense)."""
    a = np.zeros((6, 2), np.float32)
    a[[1, 3, 5], 0] = [10, 20, 30]
    ell = ell_rows_from_dense(jnp.array(a), 3)
    np.testing.assert_array_equal(np.asarray(ell.idx[:, 0]), [1, 3, 5])
    np.testing.assert_allclose(np.asarray(ell.val[:, 0]), [10, 20, 30])


def test_hybrid_split_and_spgemm(rng):
    a = random_sparse(rng, 32, 32, 0.25)
    b = random_sparse(rng, 32, 32, 0.25)
    # force a heavy column/row
    a[:, 3] = 1.0
    b[7, :] = 1.0
    k = ell_width_rule((a != 0).sum(0))
    ha = split_rows_hybrid(jnp.array(a), k, coo_cap=1024)
    hb = split_cols_hybrid(jnp.array(b), k, coo_cap=1024)
    np.testing.assert_allclose(np.asarray(ha.to_dense()), a, atol=1e-6)
    np.testing.assert_allclose(np.asarray(hb.to_dense()), b, atol=1e-6)
    got = np.asarray(hybrid_spgemm_dense(ha, hb))
    np.testing.assert_allclose(got, a @ b, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 24), m=st.integers(4, 24),
       density=st.floats(0.05, 0.6), seed=st.integers(0, 2 ** 16))
def test_roundtrip_property(n, m, density, seed):
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, n, m, density)
    k = max(1, int((a != 0).sum(0).max()))
    ell = ell_rows_from_dense(jnp.array(a), k)
    np.testing.assert_allclose(np.asarray(ell.to_dense()), a, atol=1e-6)
    # invariant: number of valid slots == nnz
    assert int(ell.valid_mask().sum()) == int((a != 0).sum())
