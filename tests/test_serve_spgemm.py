"""SparseGemmBatcher: slot-batched heterogeneous SpGEMM in the engine.

Contract under test: results from a batched wave (padded slots, per-slot
key planes, shared out_cap) are bit-identical to running each request
through the warm numeric phase alone; structures are recycled through the
shared StructureCache; occupancy/latency land in the engine stats.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.formats import ell_cols_from_dense, ell_rows_from_dense
from repro.core.spgemm import spgemm_coo_numeric
from repro.plan import StructureCache
from repro.serve import (ServeConfig, ServingEngine, SparseGemmBatcher,
                         SparseGemmRequest)


def _pair(seed, n=32, k=6):
    """Same slab widths across seeds so requests share a shape signature."""
    r = np.random.default_rng(seed)
    A = np.zeros((n, n), np.float32)
    B = np.zeros((n, n), np.float32)
    for i in range(n):
        cols = r.choice(n, size=r.integers(1, k + 1), replace=False)
        A[i, cols] = r.integers(1, 5, cols.size)
        rows = r.choice(n, size=r.integers(1, k + 1), replace=False)
        B[rows, i] = r.integers(1, 5, rows.size)
    return (ell_rows_from_dense(jnp.asarray(A), k),
            ell_cols_from_dense(jnp.asarray(B), k))


def _assert_same(got, ref):
    n = int(ref.ngroups)
    assert int(got.ngroups) == n
    np.testing.assert_array_equal(np.asarray(got.row[:n]),
                                  np.asarray(ref.row[:n]))
    np.testing.assert_array_equal(np.asarray(got.col[:n]),
                                  np.asarray(ref.col[:n]))
    np.testing.assert_array_equal(np.asarray(got.val[:n]),
                                  np.asarray(ref.val[:n]))


def test_batched_waves_bit_match_unbatched_numeric():
    cache = StructureCache(capacity=16)
    stats = {}
    bt = SparseGemmBatcher(cache, max_slots=4, stats=stats)
    pairs = {bt.submit(a, b): (a, b)
             for a, b in (_pair(s) for s in range(6))}
    assert bt.pending() == 6
    res = bt.flush()
    assert bt.pending() == 0 and set(res) == set(pairs)
    for rid, (a, b) in pairs.items():
        _assert_same(res[rid],
                     spgemm_coo_numeric(a, b, cache.get(a, b),
                                        validate=False))
    # 6 same-shape requests, 4 slots -> one full wave + one 2-slot wave
    assert stats["spgemm_requests"] == 6
    assert stats["spgemm_waves"] == 2
    assert stats["spgemm_batched_waves"] == 2
    assert abs(stats["spgemm_occupancy_sum"] - 1.5) < 1e-9
    assert stats["spgemm_compute_s"] > 0


def test_heterogeneous_shapes_group_separately():
    cache = StructureCache(capacity=16)
    stats = {}
    bt = SparseGemmBatcher(cache, max_slots=4, stats=stats)
    big = [_pair(s, n=32, k=6) for s in range(2)]
    small = [_pair(100 + s, n=16, k=4) for s in range(3)]
    rids = {bt.submit(a, b): (a, b) for a, b in big + small}
    res = bt.flush()
    for rid, (a, b) in rids.items():
        _assert_same(res[rid],
                     spgemm_coo_numeric(a, b, cache.get(a, b),
                                        validate=False))
    # one wave per shape group — shapes never mix inside a wave
    assert stats["spgemm_waves"] == 2 and stats["spgemm_batched_waves"] == 2


def test_singleton_wave_skips_batch_machinery():
    cache = StructureCache(capacity=4)
    stats = {}
    bt = SparseGemmBatcher(cache, max_slots=4, stats=stats)
    a, b = _pair(0)
    rid = bt.submit(a, b)
    res = bt.flush()
    _assert_same(res[rid],
                 spgemm_coo_numeric(a, b, cache.get(a, b), validate=False))
    assert stats["spgemm_waves"] == 1
    assert stats["spgemm_batched_waves"] == 0


def test_structures_recycled_across_flushes():
    cache = StructureCache(capacity=16)
    bt = SparseGemmBatcher(cache, max_slots=4)
    pairs = [_pair(s) for s in range(3)]
    for a, b in pairs:
        bt.submit(a, b)
    bt.flush()
    miss0 = cache.stats()["misses"]
    for a, b in pairs:                    # same patterns: hits only
        bt.submit(a, b)
    bt.flush()
    s = cache.stats()
    assert s["misses"] == miss0
    assert s["hits"] >= len(pairs)


def test_request_dataclass_and_rids_monotonic():
    bt = SparseGemmBatcher(StructureCache(capacity=2), max_slots=2)
    a, b = _pair(1)
    rids = [bt.submit(a, b) for _ in range(3)]
    assert rids == sorted(rids) and len(set(rids)) == 3
    assert all(isinstance(r, SparseGemmRequest) for r in bt._pending)


class _Stub:
    def prefill(self, *a, **k):
        raise NotImplementedError

    def decode_step(self, *a, **k):
        raise NotImplementedError


def test_engine_submit_flush_and_stats_snapshot():
    eng = ServingEngine(_Stub(), None, ServeConfig(max_batch=4))
    a, b = _pair(2)
    r1 = eng.submit_spgemm(a, b)
    r2 = eng.submit_spgemm(a, b)
    out = eng.flush_spgemm()
    assert set(out) == {r1, r2}
    ref = eng.spgemm(a, b)                # cache-backed one-shot path
    _assert_same(out[r1], ref)
    snap = eng.stats()
    assert snap["spgemm_requests"] == 2
    assert snap["spgemm_waves"] == 1 and snap["spgemm_batched_waves"] == 1
    assert 0.0 < snap["spgemm_occupancy"] <= 1.0
    assert snap["spgemm_latency_s_per_request"] > 0
    # batcher shares the engine's structure cache
    assert snap["structure_cache"]["hits"] >= 1
