"""Algorithm 1 (bit-serial in-situ minima search) kernel vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.insitu_search import (KEY_INVALID, minima_mask_pallas,
                                         search_emit_sorted)


def test_minima_mask_basic():
    v = jnp.asarray([5, 3, 9, 3, KEY_INVALID, 3], jnp.int32)
    got = np.asarray(minima_mask_pallas(v))
    np.testing.assert_array_equal(got, [False, True, False, True, False, True])


def test_minima_mask_all_invalid():
    v = jnp.full((8,), KEY_INVALID, jnp.int32)
    assert not np.asarray(minima_mask_pallas(v)).any()


def test_emit_sorted_matches_unique():
    rng = np.random.default_rng(0)
    v = rng.integers(0, 12, 64).astype(np.int32)
    vals, counts = search_emit_sorted(jnp.asarray(v), max_unique=16)
    ev, ec = ref.search_emit_sorted_ref(jnp.asarray(v), 16)
    np.testing.assert_array_equal(np.asarray(vals), ev)
    np.testing.assert_array_equal(np.asarray(counts), ec)


def test_emit_order_is_the_hardware_order():
    """Fig. 11c: values emitted strictly ascending (the sorted-COO contract)."""
    rng = np.random.default_rng(1)
    v = rng.integers(0, 1 << 20, 128).astype(np.int32)
    vals, _ = search_emit_sorted(jnp.asarray(v), max_unique=128)
    vv = np.asarray(vals)
    vv = vv[vv != int(KEY_INVALID)]
    assert (np.diff(vv) > 0).all()


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 200), hi=st.integers(1, 1 << 30),
       seed=st.integers(0, 2 ** 16))
def test_minima_mask_property(n, hi, seed):
    rng = np.random.default_rng(seed)
    v = rng.integers(0, hi, n).astype(np.int32)
    got = np.asarray(minima_mask_pallas(jnp.asarray(v)))
    exp = np.asarray(ref.minima_mask_ref(jnp.asarray(v)))
    np.testing.assert_array_equal(got, exp)
