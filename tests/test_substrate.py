"""Substrate tests: optimizer, compression, data, checkpoint, fault, serve."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset, make_host_loader
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress_int8,
                         decompress_int8)
from repro.runtime.fault import StragglerDetector, retry_with_backoff


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, warmup_steps=1, total_steps=200)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2


def test_adamw_clip_and_schedule():
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=0.5, warmup_steps=10)
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(params, g, state, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)
    assert float(metrics["lr"]) == pytest.approx(0.1, rel=1e-3)  # warmup 1/10


def test_int8_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_data_determinism_and_host_split():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    ds1, ds2 = SyntheticLMDataset(cfg), SyntheticLMDataset(cfg)
    np.testing.assert_array_equal(ds1.batch(7)["tokens"], ds2.batch(7)["tokens"])
    assert not np.array_equal(ds1.batch(7)["tokens"], ds1.batch(8)["tokens"])
    # host sharding: two hosts see different streams, shapes divide
    h0 = SyntheticLMDataset(DataConfig(vocab=1000, seq_len=64, global_batch=8,
                                       n_hosts=2, host_id=0))
    h1 = SyntheticLMDataset(DataConfig(vocab=1000, seq_len=64, global_batch=8,
                                       n_hosts=2, host_id=1))
    assert h0.batch(0)["tokens"].shape == (4, 64)
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_data_prefetcher():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    ds = SyntheticLMDataset(cfg)
    it = make_host_loader(ds, start_step=3)
    first = next(iter(it))
    np.testing.assert_array_equal(first["tokens"], ds.batch(3)["tokens"])
    it.close()


def test_checkpoint_atomic_save_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    params = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    opt = {"mu": {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros(4)}},
           "nu": {"a": jnp.ones((2, 3)), "b": {"c": jnp.ones(4)}},
           "step": jnp.array(7, jnp.int32)}
    for step in (10, 20, 30):
        mgr.save(step, params, opt, extra={"next_step": step})
    assert mgr.all_steps() == [20, 30]          # keep_n GC
    p2, o2, extra = mgr.restore(30, params, opt)
    np.testing.assert_allclose(np.asarray(p2["a"]), np.asarray(params["a"]))
    assert int(o2["step"]) == 7
    assert extra["next_step"] == 30


def test_checkpoint_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(tmp_path)
    params = {"a": jnp.ones(3)}
    opt = {"step": jnp.array(0)}
    mgr.save(5, params, opt)
    # simulate a crash mid-write: stray .tmp dir + manifest-less dir
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000007").mkdir()
    assert mgr.latest_step() == 5


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(window=32, k_sigma=4.0, persistent=3)
    for _ in range(20):
        det.record(0.1)
    assert not det.is_straggler
    for _ in range(3):
        det.record(1.5)
    assert det.is_straggler


def test_retry_with_backoff_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return 42

    assert retry_with_backoff(flaky, base_delay=0.01)() == 42
    assert calls["n"] == 3


def test_retry_gives_up():
    def always_fails():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError):
        retry_with_backoff(always_fails, max_retries=2, base_delay=0.01)()


def test_serving_engine_generates():
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve import ServeConfig, ServingEngine
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, ServeConfig(
        max_batch=4, max_new_tokens=6, s_max=48))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, cfg.vocab, size=5).astype(np.int32)
               for _ in range(3)]
    outs = eng.generate_batch(prompts)
    assert len(outs) == 3
    assert all(1 <= len(o) <= 6 for o in outs)
    assert eng.stats["tokens"] > 0
    # every emitted token is counted, including the post-prefill one
    assert eng.stats["tokens"] == sum(len(o) for o in outs)


def test_serving_engine_first_token_eos_stops():
    """Regression: a request whose FIRST sampled token is EOS must stop
    immediately — no decode steps, and the token must be counted."""
    from repro.serve import ServeConfig, ServingEngine

    cfg = ServeConfig(max_batch=2, max_new_tokens=8, s_max=16, eos_id=2)
    vocab = 8
    calls = {"decode": 0}

    class _EosModel:
        def prefill(self, params, batch, s_max):
            b = batch["tokens"].shape[0]
            logits = jnp.zeros((b, vocab)).at[:, cfg.eos_id].set(10.0)
            return logits, {"pos": jnp.zeros((), jnp.int32)}

        def decode_step(self, params, cache, tokens):
            calls["decode"] += 1
            b = tokens.shape[0]
            logits = jnp.zeros((b, vocab)).at[:, cfg.eos_id].set(10.0)
            return logits, cache

    eng = ServingEngine(_EosModel(), {}, cfg)
    outs = eng.generate_batch([np.array([3, 4], np.int32),
                               np.array([5], np.int32)])
    assert outs == [[cfg.eos_id], [cfg.eos_id]]
    assert eng.stats["tokens"] == 2
    assert calls["decode"] == 0, "no decode step after an all-EOS prefill"
