"""Per-kernel allclose vs ref.py oracles with shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.bitonic_merge import KEY_INVALID, bitonic_merge_pallas
from repro.kernels.ell_spmm import ell_spmm_pallas
from repro.kernels.sccp_multiply import sccp_multiply_pallas


def _ell_inputs(rng, ka, n, kb, occupancy=0.7, dtype=np.float32):
    a_val = (rng.standard_normal((ka, n)) * (rng.random((ka, n)) < occupancy))
    a_idx = np.where(a_val != 0, rng.integers(0, 64, (ka, n)), -1)
    b_val = (rng.standard_normal((n, kb)) * (rng.random((n, kb)) < occupancy))
    b_idx = np.where(b_val != 0, rng.integers(0, 64, (n, kb)), -1)
    return (a_val.astype(dtype), a_idx.astype(np.int32),
            b_val.astype(dtype), b_idx.astype(np.int32))


@pytest.mark.parametrize("ka,n,kb", [(1, 128, 1), (4, 256, 4), (7, 384, 3),
                                     (8, 512, 8)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_sccp_kernel_sweep(rng, ka, n, kb, dtype):
    ins = _ell_inputs(rng, ka, n, kb, dtype=dtype)
    jins = list(map(jnp.asarray, ins))
    got = sccp_multiply_pallas(*jins, block_n=128, interpret=True)
    exp = ref.sccp_multiply_ref(*jins)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-6)


def test_sccp_interpret_auto_select(rng, monkeypatch):
    """sccp_multiply_pallas defaults to the COMPILED path when the backend
    supports Pallas lowering (TPU) and to the interpreter elsewhere — the
    old hardcoded interpret=True would run the interpreter on real TPUs."""
    import repro.kernels.sccp_multiply as sm
    seen = {}
    real = sm.pl.pallas_call

    def spy(*args, **kw):
        seen["interpret"] = kw.get("interpret")
        kw["interpret"] = True          # keep it executable on this host
        return real(*args, **kw)

    monkeypatch.setattr(sm.pl, "pallas_call", spy)
    ins = list(map(jnp.asarray, _ell_inputs(rng, 2, 128, 2)))

    assert sm.auto_interpret() is True       # this host has no TPU
    sm.sccp_multiply_pallas(*ins, block_n=128)
    assert seen["interpret"] is True         # auto → interpreter off-TPU

    monkeypatch.setattr(sm.jax, "default_backend", lambda: "tpu")
    assert sm.auto_interpret() is False
    ins2 = list(map(jnp.asarray, _ell_inputs(rng, 3, 128, 2)))  # fresh trace
    got = sm.sccp_multiply_pallas(*ins2, block_n=128)
    assert seen["interpret"] is False        # auto → compiled on TPU
    exp = ref.sccp_multiply_ref(*ins2)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-6)


def test_fused_slab_sort_kernel_matches_xla(rng):
    """fused_sccp_stream: the in-VMEM multiply+sort kernel (interpret) and
    the XLA realization emit the identical stream contract (integer values
    → exact totals regardless of within-run association)."""
    from repro.kernels.fused_sccp_stream import (fused_slab_sort_pallas,
                                                 fused_slab_sort_xla)
    n, k_b, n_cols = 96, 5, 64
    a_val = jnp.asarray(rng.integers(-3, 4, n).astype(np.float32))
    a_idx = jnp.asarray(np.where(rng.random(n) < 0.7,
                                 rng.integers(0, 64, n), -1).astype(np.int32))
    b_val = jnp.asarray(rng.integers(-3, 4, (n, k_b)).astype(np.float32))
    b_idx = jnp.asarray(np.where(rng.random((n, k_b)) < 0.7,
                                 rng.integers(0, n_cols, (n, k_b)),
                                 -1).astype(np.int32))
    k1, t1 = fused_slab_sort_pallas(a_val, a_idx, b_val, b_idx,
                                    n_cols=n_cols, interpret=True)
    k2, t2 = fused_slab_sort_xla(a_val, a_idx, b_val, b_idx, n_cols=n_cols)
    assert k1.shape[0] == 512               # pot(96·5)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    kk = np.asarray(k1)
    assert (np.diff(kk) >= 0).all()
    tails = np.concatenate([kk[1:] != kk[:-1], [True]]) & (kk != KEY_INVALID)
    assert (np.asarray(t1)[~tails] == 0).all()


def test_sccp_ops_padding(rng):
    """ops wrapper pads non-128-multiple lane counts correctly."""
    ins = _ell_inputs(rng, 3, 217, 5)
    jins = list(map(jnp.asarray, ins))
    got = ops.sccp_multiply(*jins)
    exp = ref.sccp_multiply_ref(*jins)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-6)


@pytest.mark.parametrize("length", [64, 128, 1024])
def test_bitonic_merge_sweep(rng, length):
    key = rng.integers(0, 50, length).astype(np.int32)
    key[rng.random(length) < 0.2] = KEY_INVALID
    val = rng.standard_normal(length).astype(np.float32)
    k_got, v_got = bitonic_merge_pallas(jnp.asarray(key), jnp.asarray(val),
                                        interpret=True)
    k_exp, v_exp = ref.bitonic_merge_ref(jnp.asarray(key), jnp.asarray(val))
    np.testing.assert_array_equal(np.asarray(k_got), np.asarray(k_exp))
    # value placement within equal-key runs may differ; compare per-key sums
    def sums(k, v):
        out = {}
        for kk, vv in zip(np.asarray(k), np.asarray(v)):
            out[int(kk)] = out.get(int(kk), 0.0) + float(vv)
        return out
    got_s, exp_s = sums(k_got, v_got), sums(k_exp, v_exp)
    for kk in exp_s:
        np.testing.assert_allclose(got_s.get(kk, 0.0), exp_s[kk], atol=1e-3)


def test_bitonic_merge_totals_at_tails(rng):
    key = np.repeat(np.arange(8, dtype=np.int32), 16)
    val = np.ones(128, np.float32)
    k, v = bitonic_merge_pallas(jnp.asarray(key), jnp.asarray(val),
                                interpret=True)
    v = np.asarray(v)
    assert (np.sort(v[v != 0]) == 16).all()
    assert (v != 0).sum() == 8


@pytest.mark.parametrize("k,n,m,d", [(1, 128, 128, 8), (4, 256, 128, 64),
                                     (8, 128, 256, 128)])
def test_ell_spmm_kernel_sweep(rng, k, n, m, d):
    a_val = (rng.standard_normal((k, n)) * (rng.random((k, n)) < 0.6)).astype(np.float32)
    a_idx = np.where(a_val != 0, rng.integers(0, m, (k, n)), -1).astype(np.int32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    got = ell_spmm_pallas(jnp.asarray(a_val), jnp.asarray(a_idx),
                          jnp.asarray(x), n_rows=m, interpret=True)
    exp = ref.ell_spmm_ref(jnp.asarray(a_val), jnp.asarray(a_idx),
                           jnp.asarray(x), m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-3, rtol=1e-3)


def test_ell_spmm_ops_ragged(rng):
    a_val = (rng.standard_normal((3, 300))).astype(np.float32)
    a_idx = rng.integers(0, 150, (3, 300)).astype(np.int32)
    x = rng.standard_normal((300, 70)).astype(np.float32)
    got = ops.ell_spmm(jnp.asarray(a_val), jnp.asarray(a_idx),
                       jnp.asarray(x), 150)
    exp = ref.ell_spmm_ref(jnp.asarray(a_val), jnp.asarray(a_idx),
                           jnp.asarray(x), 150)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(5, 10), nkeys=st.integers(1, 60),
       seed=st.integers(0, 2 ** 16))
def test_bitonic_property(logn, nkeys, seed):
    rng = np.random.default_rng(seed)
    length = 1 << logn
    key = rng.integers(0, nkeys, length).astype(np.int32)
    val = rng.standard_normal(length).astype(np.float32)
    k, v = bitonic_merge_pallas(jnp.asarray(key), jnp.asarray(val),
                                interpret=True)
    k = np.asarray(k)
    assert (np.diff(k) >= 0).all()
    # conservation: total mass preserved
    np.testing.assert_allclose(float(np.asarray(v).sum()), float(val.sum()),
                               atol=1e-2)


@pytest.mark.parametrize("n,tile", [(512, 128), (4096, 512)])
def test_sort_merge_tree_matches_single_tile(rng, n, tile):
    """Multi-tile merge tree ≡ the monolithic single-tile network."""
    from repro.kernels.bitonic_merge import sort_merge_tree_pallas
    key = rng.integers(0, n // 4, n).astype(np.int32)
    key[rng.random(n) < 0.15] = KEY_INVALID
    val = rng.standard_normal(n).astype(np.float32)
    k_got, v_got = sort_merge_tree_pallas(jnp.asarray(key), jnp.asarray(val),
                                          tile=tile, interpret=True)
    k_exp, v_exp = ref.bitonic_merge_ref(jnp.asarray(key), jnp.asarray(val))
    np.testing.assert_array_equal(np.asarray(k_got), np.asarray(k_exp))
    kk, vv = np.asarray(k_got), np.asarray(v_got)
    tails = np.concatenate([kk[1:] != kk[:-1], [True]]) & (kk != KEY_INVALID)
    assert (vv[~tails] == 0).all(), "non-tail lanes must be zeroed"
    np.testing.assert_allclose(vv[tails], np.asarray(v_exp)[np.asarray(
        np.concatenate([np.asarray(k_exp)[1:] != np.asarray(k_exp)[:-1],
                        [True]]) & (np.asarray(k_exp) != KEY_INVALID))],
        atol=1e-3)


@settings(max_examples=6, deadline=None)
@given(logn=st.sampled_from([10, 14, 18]), logc=st.integers(4, 6),
       seed=st.integers(0, 2 ** 16))
def test_sort_merge_property_vs_accumulate(logn, logc, seed):
    """ops.sort_merge (merge tree) ≡ core accumulate up to 2^18 products."""
    from repro.core.accumulate import accumulate
    rng = np.random.default_rng(seed)
    n = 1 << logn
    n_rows = n_cols = 1 << logc
    row = rng.integers(0, n_rows, n).astype(np.int32)
    col = rng.integers(0, n_cols, n).astype(np.int32)
    bad = rng.random(n) < 0.1
    row[bad] = -1
    col[bad] = -1
    val = np.where(bad, 0, rng.standard_normal(n)).astype(np.float32)
    key, tot = ops.sort_merge(jnp.asarray(row), jnp.asarray(col),
                              jnp.asarray(val), n_rows, n_cols, tile=1024)
    kk, vv = np.asarray(key), np.asarray(tot)
    tails = np.concatenate([kk[1:] != kk[:-1], [True]]) & (kk != KEY_INVALID)
    out_cap = n_rows * n_cols
    coo = accumulate(jnp.asarray(row), jnp.asarray(col), jnp.asarray(val),
                     out_cap, n_rows, n_cols)
    m = np.asarray(coo.row) >= 0
    exp_keys = np.asarray(coo.row)[m] * n_cols + np.asarray(coo.col)[m]
    np.testing.assert_array_equal(kk[tails], exp_keys)
    np.testing.assert_allclose(vv[tails], np.asarray(coo.val)[m],
                               atol=5e-3)
    assert tails.sum() == int(coo.ngroups)


def test_bin_ranks_stable(rng):
    """bin_ranks = stable per-bucket running count; invalid lanes rank -1."""
    from repro.kernels.radix_bucket import bin_ranks_pallas
    n, n_buckets = 2048, 8
    bid = rng.integers(0, n_buckets, n).astype(np.int32)
    bid[rng.random(n) < 0.15] = -1
    got = np.asarray(bin_ranks_pallas(jnp.asarray(bid), n_buckets=n_buckets,
                                      interpret=True))
    seen = {}
    for i, b in enumerate(bid):
        if b < 0:
            assert got[i] == -1, i
        else:
            assert got[i] == seen.get(int(b), 0), i
            seen[int(b)] = seen.get(int(b), 0) + 1


@pytest.mark.parametrize("merge_kind", ["bucket", "hash"])
def test_blocked_merge_matches_ref(rng, merge_kind):
    """bucket_merge / hash_merge reproduce the sort_merge stream contract:
    per-key totals match the reference coalesce, tails sorted globally."""
    n, n_rows, n_cols = 4096, 64, 64
    row = rng.integers(0, n_rows, n).astype(np.int32)
    col = rng.integers(0, n_cols, n).astype(np.int32)
    bad = rng.random(n) < 0.1
    row[bad] = -1
    col[bad] = -1
    val = np.where(bad, 0, rng.standard_normal(n)).astype(np.float32)
    fn = ops.bucket_merge if merge_kind == "bucket" else ops.hash_merge
    kw = ({"n_buckets": 8} if merge_kind == "bucket" else {"n_blocks": 8})
    key, tot, dropped = fn(jnp.asarray(row), jnp.asarray(col),
                           jnp.asarray(val), n_rows, n_cols, **kw)
    assert int(dropped) == 0
    kk, vv = np.asarray(key), np.asarray(tot)
    tails = (np.concatenate([kk[1:] != kk[:-1], [True]])
             & (kk != KEY_INVALID))
    assert (vv[~tails] == 0).all()
    assert (np.diff(kk[tails]) > 0).all(), "tails must be globally sorted"
    ref_key = np.where(row >= 0, row * n_cols + col, int(KEY_INVALID))
    k_exp, v_exp = ref.bitonic_merge_ref(jnp.asarray(ref_key.astype(np.int32)),
                                         jnp.asarray(val))
    k_exp, v_exp = np.asarray(k_exp), np.asarray(v_exp)
    exp_tails = (np.concatenate([k_exp[1:] != k_exp[:-1], [True]])
                 & (k_exp != KEY_INVALID))
    np.testing.assert_array_equal(kk[tails], k_exp[exp_tails])
    np.testing.assert_allclose(vv[tails], v_exp[exp_tails], atol=1e-3)


def test_bucket_merge_reports_drops(rng):
    """A bucket smaller than its load must count (not silently lose) drops."""
    n, n_rows, n_cols = 1024, 8, 8
    row = np.zeros(n, np.int32)              # everything lands in bucket 0
    col = rng.integers(0, n_cols, n).astype(np.int32)
    val = np.ones(n, np.float32)
    key, tot, dropped = ops.bucket_merge(jnp.asarray(row), jnp.asarray(col),
                                         jnp.asarray(val), n_rows, n_cols,
                                         n_buckets=4, bucket_cap=128)
    assert int(dropped) == n - 128
    # hash: 2 blocks of 8-slot tables cannot hold 8 distinct cols per block
    key, tot, dropped = ops.hash_merge(jnp.asarray(row), jnp.asarray(col),
                                       jnp.asarray(val), n_rows, n_cols,
                                       n_blocks=2, block_cap=4)
    assert int(dropped) > 0
    # non-power-of-two caps are rejected at the wrapper boundary
    for bad_kw in ({"bucket_cap": 100}, ):
        with pytest.raises(ValueError):
            ops.bucket_merge(jnp.asarray(row), jnp.asarray(col),
                             jnp.asarray(val), n_rows, n_cols, **bad_kw)
    with pytest.raises(ValueError):
        ops.hash_merge(jnp.asarray(row), jnp.asarray(col),
                       jnp.asarray(val), n_rows, n_cols, block_cap=100)


@settings(max_examples=8, deadline=None)
@given(logn=st.sampled_from([12, 14]), n_buckets=st.sampled_from([2, 4, 16]),
       logc=st.integers(4, 7), seed=st.integers(0, 2 ** 16))
def test_bucket_merge_property_vs_accumulate(logn, n_buckets, logc, seed):
    """Propagation blocking ≡ core accumulate across bucket counts/shapes."""
    from repro.core.accumulate import accumulate
    rng = np.random.default_rng(seed)
    n = 1 << logn
    n_rows = n_cols = 1 << logc
    row = rng.integers(0, n_rows, n).astype(np.int32)
    col = rng.integers(0, n_cols, n).astype(np.int32)
    val = rng.standard_normal(n).astype(np.float32)
    key, tot, dropped = ops.bucket_merge(jnp.asarray(row), jnp.asarray(col),
                                         jnp.asarray(val), n_rows, n_cols,
                                         n_buckets=n_buckets)
    assert int(dropped) == 0
    kk, vv = np.asarray(key), np.asarray(tot)
    tails = (np.concatenate([kk[1:] != kk[:-1], [True]])
             & (kk != KEY_INVALID))
    coo = accumulate(jnp.asarray(row), jnp.asarray(col), jnp.asarray(val),
                     n_rows * n_cols, n_rows, n_cols)
    m = np.asarray(coo.row) >= 0
    exp_keys = np.asarray(coo.row)[m] * n_cols + np.asarray(coo.col)[m]
    np.testing.assert_array_equal(kk[tails], exp_keys)
    np.testing.assert_allclose(vv[tails], np.asarray(coo.val)[m], atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(ka=st.integers(1, 6), kb=st.integers(1, 6),
       n=st.sampled_from([128, 256]), seed=st.integers(0, 2 ** 16))
def test_sccp_property(ka, kb, n, seed):
    rng = np.random.default_rng(seed)
    ins = _ell_inputs(rng, ka, n, kb)
    jins = list(map(jnp.asarray, ins))
    got = sccp_multiply_pallas(*jins, block_n=128, interpret=True)
    exp = ref.sccp_multiply_ref(*jins)
    for g, e in zip(got, exp):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-6)


def _packed_stream(rng, n, keyspace=64 * 64):
    key = rng.integers(0, keyspace, n).astype(np.int32)
    val = rng.standard_normal(n).astype(np.float32)
    return jnp.asarray(key), jnp.asarray(val)


def test_bucket_interpret_auto_select(rng, monkeypatch):
    """bucket_merge mirrors sccp's auto-select: the XLA realization
    (bin_ranks_xla + sort_tiles_xla, zero pallas_call) off-TPU, the compiled
    Pallas kernels (interpret=False) when the backend is TPU."""
    import repro.kernels.bitonic_merge as bm
    import repro.kernels.radix_bucket as rb
    import repro.kernels.sccp_multiply as sm
    seen = []
    real = rb.pl.pallas_call          # pl is the shared pallas module

    def spy(*args, **kw):
        seen.append(kw.get("interpret"))
        kw["interpret"] = True        # keep it executable on this host
        return real(*args, **kw)

    monkeypatch.setattr(rb.pl, "pallas_call", spy)

    assert bm.resolve_mode(None) == "xla"       # this host has no TPU
    k, v = _packed_stream(rng, 512)
    key_x, tot_x, drop_x = rb.bucket_merge(
        k, v, n_buckets=4, bucket_cap=512, keys_per_bucket=1024)
    assert seen == []                 # auto → pure-XLA path, no Pallas at all

    ki, ti, di = rb.bucket_merge(k, v, n_buckets=4, bucket_cap=512,
                                 keys_per_bucket=1024, interpret=True)
    assert seen and all(i is True for i in seen)
    np.testing.assert_array_equal(np.asarray(key_x), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(tot_x), np.asarray(ti), atol=1e-5)
    assert int(drop_x) == int(di)

    seen.clear()
    monkeypatch.setattr(sm.jax, "default_backend", lambda: "tpu")
    assert bm.resolve_mode(None) == "pallas"
    k2, v2 = _packed_stream(rng, 1024)          # fresh shape → fresh trace
    rb.bucket_merge(k2, v2, n_buckets=4, bucket_cap=1024, keys_per_bucket=1024)
    assert seen and all(i is False for i in seen)   # compiled on TPU


def test_hash_interpret_auto_select(rng, monkeypatch):
    """hash_merge auto-select: probe loop is plain XLA everywhere; only the
    final table sort switches between sort_tiles_xla and compiled Pallas."""
    import repro.kernels.bitonic_merge as bm
    import repro.kernels.hash_accum as ha
    import repro.kernels.sccp_multiply as sm
    seen = []
    real = bm.pl.pallas_call          # hash_accum's only Pallas use is the
                                      # bitonic_merge sort stage

    def spy(*args, **kw):
        seen.append(kw.get("interpret"))
        kw["interpret"] = True
        return real(*args, **kw)

    monkeypatch.setattr(bm.pl, "pallas_call", spy)

    # shapes deliberately distinct from the bucket test's: the shared
    # sort_tiles_pallas jit cache would otherwise satisfy identical
    # signatures without re-tracing, blinding the spy
    assert bm.resolve_mode(None) == "xla"
    k, v = _packed_stream(rng, 512)
    key_x, tot_x, drop_x = ha.hash_merge(
        k, v, n_blocks=4, block_cap=256, keys_per_block=1024)
    assert seen == []

    ki, ti, di = ha.hash_merge(k, v, n_blocks=4, block_cap=256,
                               keys_per_block=1024, interpret=True)
    assert seen and all(i is True for i in seen)
    np.testing.assert_array_equal(np.asarray(key_x), np.asarray(ki))
    np.testing.assert_allclose(np.asarray(tot_x), np.asarray(ti), atol=1e-5)
    assert int(drop_x) == int(di)

    seen.clear()
    monkeypatch.setattr(sm.jax, "default_backend", lambda: "tpu")
    k2, v2 = _packed_stream(rng, 1024)
    ha.hash_merge(k2, v2, n_blocks=8, block_cap=256, keys_per_block=512)
    assert seen and all(i is False for i in seen)
