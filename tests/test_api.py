"""The unified ``repro.spgemm()`` front door: routing + bit-parity.

Every legacy entry point the facade wraps must round-trip bit-identically:
the facade only *routes* — same kwargs reach the same variant — so the
assertions below compare full COO leaves (row/col/val/ngroups, padding
included) with exact equality, not allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import ell_cols_from_dense, ell_rows_from_dense
from repro.core.spgemm import (spgemm_coo, spgemm_coo_batched,
                               spgemm_coo_numeric,
                               spgemm_coo_numeric_batched)
from repro.core.streaming import spgemm_coo_stream
from repro.plan import (StructureCache, make_plan, make_structure,
                        make_structure_batched)

_BACKENDS = ("sort", "tiled", "bucket", "hash", "stream", "search")


def _pair(seed=0, n=24, density=0.2):
    rng = np.random.default_rng(seed)
    A = ((rng.random((n, n)) < density)
         * rng.standard_normal((n, n))).astype(np.float32)
    B = ((rng.random((n, n)) < density)
         * rng.standard_normal((n, n))).astype(np.float32)
    ka = max(1, int((A != 0).sum(0).max()))
    kb = max(1, int((B != 0).sum(1).max()))
    return (ell_rows_from_dense(jnp.asarray(A), ka),
            ell_cols_from_dense(jnp.asarray(B), kb))


def _batched_pair(batch=3, n=16, density=0.25):
    As = np.stack([((np.random.default_rng(s).random((n, n)) < density)
                    * np.random.default_rng(s).standard_normal((n, n)))
                   .astype(np.float32) for s in range(batch)])
    Bs = np.stack([((np.random.default_rng(s + 50).random((n, n)) < density)
                    * np.random.default_rng(s + 50).standard_normal((n, n)))
                   .astype(np.float32) for s in range(batch)])
    ka = max(1, int(max((As[i] != 0).sum(0).max() for i in range(batch))))
    kb = max(1, int(max((Bs[i] != 0).sum(1).max() for i in range(batch))))
    ea = jax.vmap(lambda x: ell_rows_from_dense(x, ka))(jnp.asarray(As))
    eb = jax.vmap(lambda x: ell_cols_from_dense(x, kb))(jnp.asarray(Bs))
    return ea, eb


def _assert_coo_identical(got, ref):
    np.testing.assert_array_equal(np.asarray(got.row), np.asarray(ref.row))
    np.testing.assert_array_equal(np.asarray(got.col), np.asarray(ref.col))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(ref.val))
    np.testing.assert_array_equal(np.asarray(got.ngroups),
                                  np.asarray(ref.ngroups))
    assert got.shape == ref.shape


def test_facade_default_matches_spgemm_coo():
    a, b = _pair(0)
    _assert_coo_identical(repro.spgemm(a, b), spgemm_coo(a, b))


@pytest.mark.parametrize("backend", _BACKENDS)
def test_facade_matches_every_backend(backend):
    a, b = _pair(1)
    got = repro.spgemm(a, b, out_cap="auto", accumulator=backend)
    ref = spgemm_coo(a, b, "auto", accumulator=backend)
    _assert_coo_identical(got, ref)


def test_facade_plan_kwarg_round_trip():
    a, b = _pair(2)
    plan = make_plan(a, b, backend="tiled")
    _assert_coo_identical(repro.spgemm(a, b, plan=plan),
                          spgemm_coo(a, b, plan=plan))


def test_facade_structure_routes_to_numeric():
    a, b = _pair(3)
    st = make_structure(a, b)
    _assert_coo_identical(repro.spgemm(a, b, structure=st),
                          spgemm_coo_numeric(a, b, st))


def test_facade_stream_structure_routes_to_numeric_stream():
    a, b = _pair(4)
    st = make_structure(a, b, backend="stream")
    _assert_coo_identical(repro.spgemm(a, b, structure=st),
                          spgemm_coo_numeric(a, b, st))


def test_facade_structure_cache_warm_path():
    a, b = _pair(5)
    cache = StructureCache(capacity=4)
    st = cache.get(a, b)
    got = repro.spgemm(a, b, structure=st, validate=False)
    _assert_coo_identical(got, spgemm_coo_numeric(a, b, st, validate=False))
    assert cache.stats()["misses"] == 1


def test_facade_batched_auto_detection():
    ea, eb = _batched_pair()
    n = ea.n_rows
    got = repro.spgemm(ea, eb, out_cap=n * n)
    ref = spgemm_coo_batched(ea, eb, n * n)
    _assert_coo_identical(got, ref)
    assert got.ngroups.shape == (3,)


def test_facade_batched_structure():
    ea, eb = _batched_pair()
    st = make_structure_batched(ea, eb)
    _assert_coo_identical(repro.spgemm(ea, eb, structure=st),
                          spgemm_coo_numeric_batched(ea, eb, st))


def test_facade_explicit_stream_kwargs():
    a, b = _pair(6)
    plan = make_plan(a, b, backend="stream")
    got = repro.spgemm(a, b, accumulator="stream",
                       stream_cap=plan.stream_cap, group=plan.stream_group)
    ref = spgemm_coo_stream(a, b, stream_cap=plan.stream_cap,
                            group=plan.stream_group)
    _assert_coo_identical(got, ref)
    # planless stream spelling rides spgemm_coo's planner; same plan, same
    # floats as the dedicated streaming wrapper's own "auto"
    _assert_coo_identical(repro.spgemm(a, b, accumulator="stream"),
                          spgemm_coo_stream(a, b))


def test_facade_error_cases():
    a, b = _pair(7)
    with pytest.raises(ValueError, match="requires mesh"):
        repro.spgemm(a, b, axis="ring")
    with pytest.raises(ValueError, match="requires axis"):
        repro.spgemm(a, b, mesh=object())
    with pytest.raises(ValueError, match="3-D"):
        repro.spgemm(a, b, batched=True)
    ea, eb = _batched_pair()
    with pytest.raises(ValueError, match="plan="):
        repro.spgemm(ea, eb, accumulator="stream", stream_cap=64, group=2)


def test_top_level_import_surface():
    """Every advertised lazy name resolves (and the key ones are the same
    objects as their defining modules')."""
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    from repro.core.api import spgemm as api_spgemm
    assert repro.spgemm is api_spgemm
    from repro.plan.cache import StructureCache as SC
    assert repro.StructureCache is SC
    from repro.serve.engine import SparseGemmBatcher as SB
    assert repro.SparseGemmBatcher is SB
    with pytest.raises(AttributeError):
        repro.no_such_name


def test_examples_do_not_deep_import_core():
    """The facade contract CI greps for, asserted in-suite as well."""
    import pathlib
    import re
    root = pathlib.Path(__file__).resolve().parents[1] / "examples"
    pat = re.compile(r"from repro\.core|import repro\.core")
    offenders = [p.name for p in sorted(root.glob("*.py"))
                 if pat.search(p.read_text())]
    assert not offenders, offenders
