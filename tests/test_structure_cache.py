"""Two-phase SpGEMM: structure correctness, plan/structure staleness
validation, and the fingerprint-keyed StructureCache (LRU / disk / autotune /
thread-safety).

Values are integer-valued floats throughout: every accumulation order sums
them exactly, so numeric-vs-cold comparisons can demand bit-identity across
backends whose float summation orders differ.
"""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_with_devices
from repro.core.formats import ell_cols_from_dense, ell_rows_from_dense
from repro.core.spgemm import (spgemm_coo, spgemm_coo_batched,
                               spgemm_coo_numeric,
                               spgemm_coo_numeric_batched, spgemm_dense)
from repro.core.streaming import spgemm_coo_stream_numeric
from repro.plan import (BACKENDS, StructureCache, fingerprint, make_plan,
                        make_structure, make_structure_batched)

N, M, P = 96, 80, 72


def _int_sparse(rng, n, m, density=0.08):
    """Sparse matrix of small integer-valued float32 (exact summation)."""
    return np.where(rng.random((n, m)) < density,
                    rng.integers(-4, 5, (n, m)).astype(np.float32), 0.0)


def _pair(rng, n=N, m=M, p=P, density=0.08):
    # EllRows condenses A's columns upward (k = max nnz per column);
    # EllCols condenses B's rows leftward (k = max nnz per row)
    ad, bd = _int_sparse(rng, n, m, density), _int_sparse(rng, m, p, density)
    a = ell_rows_from_dense(jnp.asarray(ad), max(1, int((ad != 0).sum(0).max())))
    b = ell_cols_from_dense(jnp.asarray(bd), max(1, int((bd != 0).sum(1).max())))
    return a, b, ad, bd


def _perturb_pattern(ad):
    """Move one nonzero to a previously-zero slot (same shape, new pattern)."""
    out = ad.copy()
    nz = np.argwhere(out != 0)
    z = np.argwhere(out == 0)
    out[tuple(nz[0])] = 0.0
    out[tuple(z[0])] = 3.0
    return out


def _coo_eq(x, y):
    return (np.array_equal(np.asarray(x.row), np.asarray(y.row))
            and np.array_equal(np.asarray(x.col), np.asarray(y.col))
            and np.array_equal(np.asarray(x.val), np.asarray(y.val))
            and np.array_equal(np.asarray(x.ngroups), np.asarray(y.ngroups)))


# ---------------------------------------------------------------------------
# Numeric phase vs cold path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_numeric_bitident_per_backend(rng, backend):
    a, b, ad, bd = _pair(rng)
    plan = make_plan(a, b, backend=backend)
    st = make_structure(a, b, plan=plan)
    cold = spgemm_coo(a, b, plan=plan, check=True)
    warm = spgemm_coo_numeric(a, b, st, check=True)
    assert _coo_eq(cold, warm)
    # and both match the dense oracle
    dense = np.zeros((N, P), np.float32)
    r, c, v = (np.asarray(warm.row), np.asarray(warm.col),
               np.asarray(warm.val))
    ok = r >= 0
    np.add.at(dense, (r[ok], c[ok]), v[ok])
    np.testing.assert_array_equal(dense, ad @ bd)


def test_numeric_structure_row_nnz_seg(rng):
    a, b, ad, bd = _pair(rng)
    st = make_structure(a, b)
    ref_rows = ((ad != 0).astype(np.int64) @ (bd != 0).astype(np.int64) > 0)
    np.testing.assert_array_equal(np.asarray(st.row_nnz), ref_rows.sum(1))
    np.testing.assert_array_equal(
        np.asarray(st.seg), np.concatenate([[0], ref_rows.sum(1).cumsum()]))
    assert int(st.nnz) == int(ref_rows.sum())


def test_numeric_value_only_update_reuses_structure(rng):
    a, b, ad, _ = _pair(rng)
    st = make_structure(a, b)
    a2 = ell_rows_from_dense(jnp.asarray(ad * 5), a.val.shape[0])
    warm = spgemm_coo_numeric(a2, b, st)       # validates: same fingerprint
    cold = spgemm_coo(a2, b, out_cap=st.out_cap)
    assert _coo_eq(cold, warm)


def test_numeric_stream_entry_point(rng):
    a, b, _, _ = _pair(rng)
    st = make_structure(a, b, backend="stream")
    cold = spgemm_coo(a, b, plan=st.plan)
    assert _coo_eq(cold, spgemm_coo_stream_numeric(a, b, st))


def test_numeric_batched_bitident(rng):
    bsz = 3
    ads = np.stack([_int_sparse(rng, N, M) for _ in range(bsz)])
    bds = np.stack([_int_sparse(rng, M, P) for _ in range(bsz)])
    ka = max(1, int((ads != 0).sum(1).max()))   # per-column, over the batch
    kb = max(1, int((bds != 0).sum(2).max()))   # per-row, over the batch
    a = jax.vmap(lambda d: ell_rows_from_dense(d, ka))(jnp.asarray(ads))
    b = jax.vmap(lambda d: ell_cols_from_dense(d, kb))(jnp.asarray(bds))
    st = make_structure_batched(a, b)
    warm = spgemm_coo_numeric_batched(a, b, st, check=True)
    plan = make_plan(
        ell_rows_from_dense(jnp.asarray(ads[0]), ka),
        ell_cols_from_dense(jnp.asarray(bds[0]), kb),
        out_cap=st.out_cap, backend="sort")
    cold = spgemm_coo_batched(a, b, plan=dataclasses.replace(plan, fp=None),
                              check=True)
    assert _coo_eq(cold, warm)


def test_numeric_distributed_bitident():
    out = run_with_devices("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.formats import ell_rows_from_dense, ell_cols_from_dense
from repro.core.distributed import spgemm_coo_sharded, spgemm_coo_sharded_numeric
from repro.plan import make_structure

rng = np.random.default_rng(7)
def mk(n, m):
    return np.where(rng.random((n, m)) < 0.08,
                    rng.integers(-4, 5, (n, m)).astype(np.float32), 0.0)
ad, bd = mk(64, 96), mk(96, 80)
a = ell_rows_from_dense(jnp.asarray(ad), max(1, int((ad != 0).sum(0).max())))
b = ell_cols_from_dense(jnp.asarray(bd), max(1, int((bd != 0).sum(1).max())))
mesh = Mesh(np.array(jax.devices()), ("x",))
st = make_structure(a, b, n_dev=4, schedules=("ring", "cstat"))
cold = spgemm_coo_sharded(a, b, mesh, "x", check=True)
warm = spgemm_coo_sharded_numeric(a, b, mesh, "x", st, check=True)
assert np.array_equal(np.asarray(cold.row), np.asarray(warm.row))
assert np.array_equal(np.asarray(cold.val), np.asarray(warm.val))
assert int(cold.ngroups) == int(warm.ngroups)
for sched in ("ring", "cstat"):
    again = spgemm_coo_sharded(a, b, mesh, "x", schedule=sched,
                               structure=st, check=True)
    assert np.array_equal(np.asarray(again.val), np.asarray(cold.val))
print("DIST-NUMERIC-OK")
""", n_devices=4)
    assert "DIST-NUMERIC-OK" in out


# ---------------------------------------------------------------------------
# Staleness validation
# ---------------------------------------------------------------------------

def test_stale_plan_raises_and_optout(rng):
    a, b, ad, _ = _pair(rng)
    plan = make_plan(a, b)
    a2 = ell_rows_from_dense(jnp.asarray(_perturb_pattern(ad)),
                             a.val.shape[0])
    with pytest.raises(ValueError, match="stale plan"):
        spgemm_coo(a2, b, plan=plan)
    # the documented opt-out for deliberate cross-pattern reuse
    spgemm_coo(a2, b, plan=dataclasses.replace(plan, fp=None))


def test_stale_structure_raises(rng):
    a, b, ad, _ = _pair(rng)
    st = make_structure(a, b)
    a2 = ell_rows_from_dense(jnp.asarray(_perturb_pattern(ad)),
                             a.val.shape[0])
    with pytest.raises(ValueError, match="stale structure"):
        spgemm_coo_numeric(a2, b, st)
    # validate=False never crashes — unknown keys park in the dump slot
    spgemm_coo_numeric(a2, b, st, validate=False)


def test_fingerprint_semantics(rng):
    a, b, ad, _ = _pair(rng)
    a_scaled = ell_rows_from_dense(jnp.asarray(ad * 2), a.val.shape[0])
    assert fingerprint(a, b) == fingerprint(a_scaled, b)
    a_moved = ell_rows_from_dense(jnp.asarray(_perturb_pattern(ad)),
                                  a.val.shape[0])
    assert fingerprint(a, b) != fingerprint(a_moved, b)


# ---------------------------------------------------------------------------
# StructureCache
# ---------------------------------------------------------------------------

def test_cache_hit_on_value_only_change(rng):
    a, b, ad, _ = _pair(rng)
    cache = StructureCache(capacity=4)
    st1 = cache.get(a, b)
    a2 = ell_rows_from_dense(jnp.asarray(ad * 7), a.val.shape[0])
    st2 = cache.get(a2, b)
    assert st2 is st1
    s = cache.stats()
    assert (s["hits"], s["misses"]) == (1, 1)


def test_cache_miss_on_pattern_change(rng):
    a, b, ad, _ = _pair(rng)
    cache = StructureCache(capacity=4)
    cache.get(a, b)
    a2 = ell_rows_from_dense(jnp.asarray(_perturb_pattern(ad)),
                             a.val.shape[0])
    st2 = cache.get(a2, b)
    assert cache.stats()["misses"] == 2
    # and the fresh structure is valid for the new pattern
    assert _coo_eq(spgemm_coo(a2, b, out_cap=st2.out_cap),
                   spgemm_coo_numeric(a2, b, st2))


def test_cache_lru_eviction_order(rng):
    _, b, _, _ = _pair(rng)
    mats = []
    for s in range(3):
        ad = _int_sparse(np.random.default_rng(50 + s), N, M)
        mats.append(ell_rows_from_dense(
            jnp.asarray(ad), max(1, int((ad != 0).sum(0).max()))))
    cache = StructureCache(capacity=2)
    cache.get(mats[0], b)
    cache.get(mats[1], b)
    cache.get(mats[0], b)           # touch 0 → 1 is now least-recent
    cache.get(mats[2], b)           # evicts 1, not 0
    assert cache.stats()["evictions"] == 1
    base = cache.stats()["hits"]
    cache.get(mats[0], b)           # survived → hit
    assert cache.stats()["hits"] == base + 1
    cache.get(mats[1], b)           # evicted → miss (rebuild)
    assert cache.stats()["misses"] == 4


def test_cache_disk_round_trip(rng, tmp_path):
    a, b, _, _ = _pair(rng)
    c1 = StructureCache(capacity=4, cache_dir=str(tmp_path))
    st1 = c1.get(a, b, n_dev=2, schedules=("ring",))
    c2 = StructureCache(capacity=4, cache_dir=str(tmp_path))
    st2 = c2.get(a, b)
    assert c2.stats() == dict(hits=0, misses=0, evictions=0, disk_hits=1,
                              autotuned=0, size=1)
    assert np.array_equal(np.asarray(st1.key), np.asarray(st2.key))
    assert st2.plan == st1.plan
    assert st2.dist_plan("ring") == st1.dist_plan("ring")
    assert _coo_eq(spgemm_coo_numeric(a, b, st1),
                   spgemm_coo_numeric(a, b, st2))
    # a corrupt file is a plain miss, never an error
    for f in tmp_path.iterdir():
        f.write_bytes(b"not an npz")
    c3 = StructureCache(capacity=4, cache_dir=str(tmp_path))
    c3.get(a, b)
    assert c3.stats()["disk_hits"] == 0 and c3.stats()["misses"] == 1


def test_cache_autotune_records_probes(rng):
    a, b, _, _ = _pair(rng)
    cache = StructureCache(capacity=4, autotune=True, probe_iters=1,
                           autotune_backends=("sort", "hash"))
    st = cache.get(a, b)
    assert cache.stats()["autotuned"] == 1
    assert st.plan.backend in ("sort", "hash")
    assert set(st.plan.est["autotune_us"]) == {"sort", "hash"}
    assert _coo_eq(spgemm_coo(a, b, plan=st.plan),
                   spgemm_coo_numeric(a, b, st))
    cache.get(a, b)                 # warm: no re-probe
    assert cache.stats()["autotuned"] == 1


def test_cache_thread_safety(rng):
    a, b, ad, _ = _pair(rng)
    a2 = ell_rows_from_dense(jnp.asarray(_perturb_pattern(ad)),
                             a.val.shape[0])
    cache = StructureCache(capacity=8)
    errors = []

    def worker(op):
        try:
            for _ in range(6):
                st = cache.get(op, b)
                st.validate(op, b)
        except Exception as exc:  # noqa: BLE001 — surface any thread failure
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(a if i % 2 else a2,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    s = cache.stats()
    assert s["hits"] + s["misses"] == 48 and s["size"] == 2


# ---------------------------------------------------------------------------
# Model / serve rewiring
# ---------------------------------------------------------------------------

def test_sparse_linear_two_phase(rng):
    from repro.models.sparse import SparseLinear
    w = rng.standard_normal((M, P)).astype(np.float32)
    layer = SparseLinear(jnp.asarray(w), sparsity=0.8)
    xd = _int_sparse(rng, 24, M, density=0.2)
    xa = ell_rows_from_dense(jnp.asarray(xd),
                             max(1, int((xd != 0).sum(0).max())))
    coo1 = layer.matmul_sparse(xa)
    coo2 = layer.matmul_sparse(xa)
    assert layer.cache.stats()["hits"] == 1
    assert _coo_eq(coo1, coo2)
    dense = np.zeros((24, P), np.float32)
    r, c, v = (np.asarray(coo1.row), np.asarray(coo1.col),
               np.asarray(coo1.val))
    ok = r >= 0
    np.add.at(dense, (r[ok], c[ok]), v[ok])
    np.testing.assert_allclose(dense, np.asarray(spgemm_dense(xa, layer.w_ell)),
                               rtol=1e-5, atol=1e-5)


def test_sparse_mlp_shares_cache(rng):
    from repro.models.ffn import SparseMLP
    w_in = rng.standard_normal((32, 48)).astype(np.float32)
    w_out = rng.standard_normal((48, 32)).astype(np.float32)
    mlp = SparseMLP(jnp.asarray(w_in), jnp.asarray(w_out), sparsity=0.7)
    assert mlp.fc_in.cache is mlp.fc_out.cache is mlp.cache
    y = mlp(jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32)))
    assert y.shape == (4, 32)
    assert mlp.cache_stats()["size"] == 0   # dense applies need no structure


def test_engine_level_structure_cache(rng, tmp_path):
    from repro.serve.engine import ServeConfig, ServingEngine

    class _Stub:                    # engine jits lazily; never called here
        def decode_step(self, p, c, t):
            raise NotImplementedError

        def prefill(self, p, batch, s_max):
            raise NotImplementedError

    eng = ServingEngine(_Stub(), {}, ServeConfig(
        structure_cache_size=4, structure_cache_dir=str(tmp_path)))
    a, b, _, _ = _pair(rng)
    coo1 = eng.spgemm(a, b)
    coo2 = eng.spgemm(a, b)
    assert _coo_eq(coo1, coo2)
    assert eng.cache_stats()["hits"] == 1
    # a restarted engine warm-starts from the shared cache dir
    eng2 = ServingEngine(_Stub(), {}, ServeConfig(
        structure_cache_size=4, structure_cache_dir=str(tmp_path)))
    eng2.spgemm(a, b)
    assert eng2.cache_stats()["disk_hits"] == 1
