"""GPipe pipeline over 8 fake devices matches sequential execution."""
from conftest import run_with_devices


def test_pipeline_matches_sequential():
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.parallel.pipeline import pipeline_apply
n_stages, n_micro, mb, d = 8, 6, 4, 16
rng = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(rng, (n_stages, d, d)) * 0.3,
          "b": jax.random.normal(jax.random.PRNGKey(1), (n_stages, d)) * 0.1}
x = jax.random.normal(jax.random.PRNGKey(2), (n_micro, mb, d))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

# sequential reference
ref = x
for s in range(n_stages):
    ref = stage_fn({"w": params["w"][s], "b": params["b"][s]}, ref)

mesh = jax.make_mesh((8,), ("pipe",))
out = pipeline_apply(stage_fn, params, x, mesh, axis="pipe")
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("OK")
""")
