"""Per-arch smoke tests (reduced configs) + decode-vs-full consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config
from repro.models import build_model

ALL_ARCHS = list(ARCHS)


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jax.random.randint(rng, (b, s), 3, cfg.vocab)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(rng, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (b, cfg.n_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """Reduced same-family config: one loss + one grad step, finite."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_full_forward(arch):
    """prefill(prompt) + decode steps must reproduce the full forward's
    next-token logits at every position — the strongest cache-correctness
    check we have (covers KV, MLA-latent, conv/SSM/LRU, and ring caches)."""
    cfg = get_config(arch + "-smoke")
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    b, s = 2, 12
    batch = _batch(cfg, rng, b=b, s=s)
    toks = batch["tokens"]

    # full-forward logits for positions 0..s-1
    if cfg.family == "audio":
        from repro.models import encdec
        enc_out = encdec.encode(params, batch["frames"], cfg)
        full_logits, _ = encdec.decode_full(params, toks, enc_out, cfg)
    else:
        from repro.models import transformer
        prefix = batch.get("patches")
        full_logits, _, _ = transformer.decoder_forward(
            params, toks, cfg, prefix_embed=prefix)
        if prefix is not None:
            full_logits = full_logits[:, prefix.shape[1]:]
    full_logits = np.asarray(full_logits, np.float32)

    # prefill on the first s0 tokens, then decode the rest one by one
    s0 = s // 2
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :s0]
    prefix_len = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    logits, cache = model.prefill(params, pre_batch,
                                  s_max=s + prefix_len + 4)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               full_logits[:, s0 - 1], rtol=0.15, atol=0.05)
    for t in range(s0, s):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits, np.float32),
                                   full_logits[:, t], rtol=0.15, atol=0.05,
                                   err_msg=f"{arch} step {t}")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_input_specs_cover_all_cells(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    for case in applicable_shapes(cfg):
        specs = model.input_specs(case)
        assert "tokens" in specs
        if case.kind == "decode":
            assert specs["tokens"].shape == (case.global_batch, 1)
        else:
            total = specs["tokens"].shape[1] + (
                cfg.n_vision_tokens if cfg.family == "vlm" else 0)
            assert total == case.seq_len
    if not cfg.sub_quadratic:
        names = [c.name for c in applicable_shapes(cfg)]
        assert "long_500k" not in names   # documented skip


def test_param_counts_close_to_published():
    """Sanity: constructed parameter totals are in the right ballpark."""
    targets = {
        "mistral-large-123b": 123e9, "qwen1.5-110b": 111e9,
        "qwen2-0.5b": 0.49e9, "yi-34b": 34e9, "falcon-mamba-7b": 7.3e9,
        "deepseek-v2-lite-16b": 16e9, "whisper-medium": 0.76e9,
        "recurrentgemma-9b": 9.6e9, "internvl2-2b": 2.2e9,
    }
    for name, tgt in targets.items():
        model = build_model(get_config(name))
        got = model.n_params()
        assert 0.55 * tgt < got < 1.6 * tgt, (name, got, tgt)


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-v2-lite-16b")
    assert cfg.active_params() < 0.35 * build_model(cfg).n_params()


def test_hybrid_pattern_layout():
    from repro.models.transformer import segment_plan
    cfg = get_config("recurrentgemma-9b")
    plan = segment_plan(cfg)
    total = sum(len(unit) * reps for unit, reps in plan)
    assert total == cfg.n_layers == 38
    assert plan[0][0] == ("rec", "rec", "local")


def test_deepseek_first_dense_layer():
    from repro.models.transformer import segment_plan
    cfg = get_config("deepseek-v2-lite-16b")
    plan = segment_plan(cfg)
    assert plan[0] == (("mla_dense",), 1)
    assert plan[1] == (("mla_moe",), 26)
