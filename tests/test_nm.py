"""N:M fast path: pruning balance, condensed format, gather-free SpMM.

The bit-identity contract under test: for an N:M-balanced pruned weight,
``nm_spmm`` (XLA realization and interpret-mode Pallas), the ELLPACK
fallback (``sparse_linear_apply`` on the lossless ``ell_from_pruned``) and
the dense oracle all produce the SAME floats — integer-valued operands make
every accumulation order exact, so the comparisons below are exact
equality, not allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _propcheck import given, settings, strategies as st

from repro.core.nm import (NM_CANDIDATES, NmWeights, detect_nm,
                           is_nm_balanced, nm_from_dense)
from repro.kernels.nm_spmm import nm_spmm
from repro.models.sparse import (SparseLinear, ell_from_pruned,
                                 magnitude_prune_nm, nm_linear_apply,
                                 sparse_linear_apply)
from repro.plan import plan_spmm_format

# (t, d_in, d_out, n, m) — shape zoo crossing window sizes and non-square
_ZOO = [
    (8, 16, 12, 2, 4),
    (16, 64, 48, 2, 4),
    (4, 32, 40, 1, 4),
    (8, 64, 24, 4, 8),
    (8, 48, 16, 2, 8),
]


def _int_mat(rng, shape):
    """Integer-valued float32 — float sums are order-exact."""
    return jnp.asarray(rng.integers(-4, 5, shape), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(d_out=st.integers(1, 24), windows=st.integers(1, 8),
       nm=st.sampled_from(list(NM_CANDIDATES)), seed=st.integers(0, 2**31))
def test_magnitude_prune_nm_exactly_balanced(d_out, windows, nm, seed):
    n, m = nm
    d_in = windows * m
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    wp = magnitude_prune_nm(w, n, m)
    per_window = np.asarray(
        (wp != 0).reshape(d_in // m, m, d_out).sum(axis=1))
    # continuous weights: every window keeps exactly its n largest
    assert (per_window == n).all()
    assert bool(is_nm_balanced(wp, n, m))
    # kept entries are untouched, dropped entries are exact zeros
    kept = np.asarray(wp != 0)
    assert np.array_equal(np.asarray(wp)[kept], np.asarray(w)[kept])


def test_magnitude_prune_nm_keeps_largest():
    w = jnp.asarray([[4.0, -9.0, 1.0, 3.0]], jnp.float32).T   # one window
    wp = magnitude_prune_nm(w, 2, 4)
    np.testing.assert_array_equal(np.asarray(wp).ravel(), [4.0, -9.0, 0, 0])


def test_nm_from_dense_round_trip_and_layout():
    rng = np.random.default_rng(3)
    wp = magnitude_prune_nm(_int_mat(rng, (32, 12)), 2, 4)
    w_nm = nm_from_dense(wp, 2, 4)
    assert isinstance(w_nm, NmWeights)
    assert w_nm.val.shape == (16, 12) and w_nm.off.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(w_nm.to_dense()),
                                  np.asarray(wp))
    # pytree round trip (jit through the container)
    f = jax.jit(lambda t: t.to_dense())
    np.testing.assert_array_equal(np.asarray(f(w_nm)), np.asarray(wp))


def test_nm_from_dense_validation():
    w = jnp.ones((12, 4), jnp.float32)
    with pytest.raises(ValueError):
        nm_from_dense(w, 2, 8)             # d_in % m != 0
    with pytest.raises(ValueError):
        nm_from_dense(w, 2, 4)             # dense rows: 4 nnz in a 4-window


@pytest.mark.parametrize("t,d_in,d_out,n,m", _ZOO)
def test_nm_spmm_bit_matches_dense_and_ellpack(t, d_in, d_out, n, m):
    rng = np.random.default_rng(d_in * 31 + d_out)
    wp = magnitude_prune_nm(_int_mat(rng, (d_in, d_out)), n, m)
    x = _int_mat(rng, (t, d_in))
    w_nm = nm_from_dense(wp, n, m)
    ref = np.asarray(x @ wp)
    got_xla = np.asarray(nm_spmm(x, w_nm.val, w_nm.off, n=n, m=m))
    got_pallas = np.asarray(
        nm_spmm(x, w_nm.val, w_nm.off, n=n, m=m, interpret=True))
    got_ell = np.asarray(sparse_linear_apply(x, ell_from_pruned(wp)))
    np.testing.assert_array_equal(got_xla, ref)
    np.testing.assert_array_equal(got_pallas, ref)
    np.testing.assert_array_equal(got_ell, ref)


def test_nm_spmm_jit_and_batched():
    rng = np.random.default_rng(11)
    wp = magnitude_prune_nm(_int_mat(rng, (32, 24)), 2, 4)
    w_nm = nm_from_dense(wp, 2, 4)
    x = _int_mat(rng, (6, 32))
    f = jax.jit(lambda xx: nm_spmm(xx, w_nm.val, w_nm.off, n=2, m=4))
    np.testing.assert_array_equal(np.asarray(f(x)), np.asarray(x @ wp))
    xb = _int_mat(rng, (3, 5, 32))
    got = nm_linear_apply(xb, w_nm)        # leading axes flattened inside
    np.testing.assert_array_equal(np.asarray(got), np.asarray(xb @ wp))
    got_v = jax.vmap(lambda xx: nm_spmm(xx, w_nm.val, w_nm.off, n=2, m=4))(xb)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(xb @ wp))


def test_detect_nm_and_planner_routing():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    wp = magnitude_prune_nm(w, 2, 4)
    assert detect_nm(wp) == (2, 4)
    fmt, shape = plan_spmm_format(wp)
    assert (fmt, shape) == ("nm", (2, 4))
    # a 2:4-balanced matrix is also 4:8-balanced; the planner prefers the
    # tighter (first-listed) candidate
    fmt_dense, shape_dense = plan_spmm_format(w)
    assert (fmt_dense, shape_dense) == ("ellpack", None)


def test_sparse_linear_nm_routes_and_bit_matches_fallback():
    rng = np.random.default_rng(7)
    w = _int_mat(rng, (64, 48))
    x = _int_mat(rng, (9, 64))
    lyr = SparseLinear(w, 0.5, nm=(2, 4))
    assert lyr.w_nm is not None and (lyr.w_nm.n, lyr.w_nm.m) == (2, 4)
    wp = magnitude_prune_nm(w, 2, 4)
    ref = np.asarray(sparse_linear_apply(x, ell_from_pruned(wp)))
    np.testing.assert_array_equal(np.asarray(lyr(x)), ref)
    # auto mode detects the balanced pattern the explicit prune produced
    lyr_auto = SparseLinear(np.asarray(wp), 0.5, nm="auto")
    assert lyr_auto.w_nm is not None
    np.testing.assert_array_equal(np.asarray(lyr_auto(x)), ref)
    # nm=None keeps the legacy ELLPACK-only layer
    lyr_off = SparseLinear(w, 0.5, nm=None)
    assert lyr_off.w_nm is None
