"""Multi-device behaviour on 8 fake host devices (subprocess-isolated)."""
import pytest

from conftest import run_with_devices


def test_ring_spgemm_8dev():
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.core import ell_rows_from_dense, ell_cols_from_dense
from repro.core.distributed import ring_spgemm
rng = np.random.default_rng(1)
n = 32
A = ((rng.random((n,n)) < 0.25) * rng.standard_normal((n,n))).astype(np.float32)
B = ((rng.random((n,n)) < 0.25) * rng.standard_normal((n,n))).astype(np.float32)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
mesh = jax.make_mesh((8,), ("ring",))
C = ring_spgemm(a, b, mesh, "ring")
np.testing.assert_allclose(np.asarray(C), A@B, atol=1e-4)
print("OK")
""")


def test_ring_all_to_all_matches_transpose():
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.core.distributed import ring_all_to_all
mesh = jax.make_mesh((8,), ("ring",))
x = jnp.arange(8*8*4, dtype=jnp.float32).reshape(8, 8, 4)
out = shard_map(lambda xs: ring_all_to_all(xs[0], "ring")[None],
                mesh=mesh, in_specs=P("ring"), out_specs=P("ring"))(x)
np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.swapaxes(x, 0, 1)))
print("OK")
""")


def test_sharded_train_step_runs_dp_tp():
    """Real train step on a 4×2 (data×model) mesh with a reduced config."""
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.launch.steps import make_train_step, abstract_train_args
from repro.optim import AdamWConfig, adamw_init
from repro.parallel.sharding import sharding_rules
import dataclasses
cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                          d_model=64, vocab=256)
mesh = jax.make_mesh((4, 2), ("data", "model"))
model = build_model(cfg)
with sharding_rules(mesh), mesh:
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, AdamWConfig()), donate_argnums=(0,1))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)}
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
print("OK")
""")


def test_moe_expert_parallel_equivalence():
    """MoE loss identical on 1 device vs expert-sharded 8 devices."""
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.parallel.sharding import sharding_rules
cfg = get_config("granite-moe-3b-a800m").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)}
l1 = float(model.loss(params, batch))
mesh = jax.make_mesh((1, 8), ("data", "model"))
with sharding_rules(mesh), mesh:
    l8 = float(jax.jit(model.loss)(params, batch))
np.testing.assert_allclose(l1, l8, rtol=2e-2)
print("OK")
""")


def test_moe_sort_dispatch_sharded_equivalence():
    """SPLIM sort dispatch (manual shard_map) matches single-device loss."""
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.parallel.sharding import sharding_rules
base = get_config("deepseek-v2-lite-16b").reduced()
cfg = dataclasses.replace(base, moe=dataclasses.replace(
    base.moe, dispatch="sort", capacity_factor=4.0))
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)}
l1 = float(model.loss(params, batch))
mesh = jax.make_mesh((2, 4), ("data", "model"))
with sharding_rules(mesh), mesh:
    l8 = float(jax.jit(model.loss)(params, batch))
np.testing.assert_allclose(l1, l8, rtol=2e-2)
print("OK")
""")


def test_compressed_psum_mean_8dev():
    run_with_devices("""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.optim import compressed_psum_mean
mesh = jax.make_mesh((8,), ("data",))
g = jnp.linspace(-1, 1, 8*32).reshape(8, 32).astype(jnp.float32)
def f(gs):
    mean, err = compressed_psum_mean({"g": gs[0]}, "data")
    return mean["g"][None], err["g"][None]
mean, err = shard_map(f, mesh=mesh, in_specs=P("data"),
                      out_specs=P("data"))(g)
true = np.asarray(g).mean(0)
got = np.asarray(mean)[0]
np.testing.assert_allclose(got, true, atol=0.02)
# error feedback bounded by one quantization step
assert np.abs(np.asarray(err)).max() <= np.abs(np.asarray(g)).max()/127 + 1e-6
print("OK")
""")
