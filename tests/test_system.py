"""End-to-end behaviour tests: training improves loss, resume works, the
loss implementations agree, MoE dispatch variants agree, and hwmodel
reproduces the paper's headline means."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def test_training_reduces_loss(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    tcfg = TrainerConfig(steps=30, log_every=5, ckpt_every=100,
                         ckpt_dir=str(tmp_path), global_batch=8, seq_len=64)
    out = Trainer(model, tcfg, AdamWConfig(lr=3e-3, warmup_steps=5)).run(
        resume=False)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.2, losses


def test_checkpoint_resume_continues(tmp_path):
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg)
    t1 = TrainerConfig(steps=10, log_every=2, ckpt_every=10,
                       ckpt_dir=str(tmp_path), global_batch=4, seq_len=32)
    Trainer(model, t1, AdamWConfig(lr=1e-3)).run(resume=False)
    # second run extends to 14 steps and must resume from step 10
    t2 = dataclasses.replace(t1, steps=14)
    trainer = Trainer(model, t2, AdamWConfig(lr=1e-3))
    out = trainer.run(resume=True)
    steps = [h["step"] for h in out["history"]]
    assert min(steps) >= 10, f"should resume at step 10, got {steps}"


def test_sharded_loss_matches_naive():
    from repro.models.common import next_token_loss, sharded_softmax_xent
    rng = jax.random.PRNGKey(0)
    b, s, d, v = 2, 8, 16, 32
    x = jax.random.normal(rng, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v)) * 0.1
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    naive = next_token_loss((x @ w)[:, :, :], toks, z_loss=0.0)
    shard = sharded_softmax_xent(x, w, toks, z_loss=0.0)
    np.testing.assert_allclose(float(naive), float(shard), rtol=1e-5)


def test_moe_dispatch_variants_agree():
    """'ellpack' (one-hot), 'sort' (SPLIM-style) and 'spmm' (routing matrix
    as row-wise ELLPACK through the SpGEMM stack) dispatch must agree when
    capacity is ample (no token drops)."""
    base = get_config("granite-moe-3b-a800m").reduced()
    toks = jax.random.randint(jax.random.PRNGKey(0), (2, 16), 0, base.vocab)
    losses = {}
    for disp in ("ellpack", "sort", "spmm"):
        cfg = dataclasses.replace(
            base, moe=dataclasses.replace(base.moe, dispatch=disp,
                                          capacity_factor=4.0))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        losses[disp] = float(model.loss(params, {"tokens": toks}))
    np.testing.assert_allclose(losses["ellpack"], losses["sort"], rtol=1e-3)
    np.testing.assert_allclose(losses["ellpack"], losses["spmm"], rtol=1e-3)


def test_hwmodel_reproduces_paper_means():
    from benchmarks.common import all_stats
    from repro.core import hwmodel
    stats = all_stats()
    cal = hwmodel.calibrate(stats)
    t_splim = np.array([hwmodel.splim_latency(s)["total"] for s in stats])
    t_gpu = np.array([hwmodel.gpu_latency(s) * cal["gpu_perf"] for s in stats])
    assert np.mean(t_gpu / t_splim) == pytest.approx(275.74, rel=1e-3)
    e_splim = np.array([hwmodel.splim_energy(s)["total"] for s in stats])
    e_gpu = np.array([hwmodel.gpu_energy(s) * cal["gpu_energy"] for s in stats])
    assert np.mean(e_gpu / e_splim) == pytest.approx(687.19, rel=1e-3)


def test_hwmodel_sensitivity_directions():
    """Paper §VI-C: sparser ⇒ faster; smaller σ ⇒ faster; more PEs ⇒ faster."""
    import math
    from benchmarks.common import all_stats
    from benchmarks.paper_figures import _scaled_stats
    from repro.core import hwmodel
    s = all_stats()[0]
    t1 = hwmodel.splim_latency(s)["total"]
    assert hwmodel.splim_latency(_scaled_stats(s, 0.5))["total"] < t1
    k_small = max(1, int(math.ceil(s.nnz_a / s.n + s.sigma / 3)))
    s_sig = dataclasses.replace(s, k_a=k_small, k_b=k_small)
    assert hwmodel.splim_latency(s_sig)["total"] < t1
    cfg8 = dataclasses.replace(hwmodel.SplimConfig(), n_pes=8)
    assert hwmodel.splim_latency(s, cfg8)["total"] > t1


def test_splim_beats_coo_splim_everywhere():
    """§IV-C: the SCCP paradigm dominates the decompression paradigm."""
    from benchmarks.common import all_stats
    from repro.core import hwmodel
    for s in all_stats():
        t = hwmodel.splim_latency(s)["total"]
        t_coo = hwmodel.coo_splim_latency(s)["total"]
        assert t < t_coo, (s.n, t, t_coo)
