"""Observability tests: the disabled-overhead contract, span nesting (jit,
threads), Chrome-trace export, metrics stability, exactly-once poison /
overflow events, cache-stats snapshots and the roofline join."""
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.obs as obs
from repro.obs import metrics as mt
from repro.obs import trace as tr


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with a disabled, empty tracer/registry."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _operands(n=64, dens=0.08, seed=0):
    from repro.core import ell_cols_from_dense, ell_rows_from_dense
    rng = np.random.default_rng(seed)
    A = ((rng.random((n, n)) < dens)
         * rng.standard_normal((n, n))).astype(np.float32)
    B = ((rng.random((n, n)) < dens)
         * rng.standard_normal((n, n))).astype(np.float32)
    a = ell_rows_from_dense(jnp.asarray(A), max(1, int((A != 0).sum(0).max())))
    b = ell_cols_from_dense(jnp.asarray(B), max(1, int((B != 0).sum(1).max())))
    return a, b


# ---------------------------------------------------------------- overhead


def test_disabled_span_is_shared_singleton():
    """Disabled tracing allocates no trace state: span() hands back one
    module-level null object, sync is identity, nothing is recorded."""
    from repro.core import spgemm_coo
    assert tr.span("anything") is tr.NULL_SPAN
    assert tr.span("other") is tr.NULL_SPAN
    x = jnp.ones(3)
    assert tr.sync(x) is x
    tr.instant("nope", k=1)
    mt.inc("nope")
    mt.observe("nope", 1.0)
    mt.record_plan("fp", "sort", {"cost_sort": 1.0})
    a, b = _operands()
    spgemm_coo(a, b, out_cap=2048, accumulator="sort")
    snap = obs.snapshot()
    assert snap["trace"]["events"] == []
    assert snap["metrics"]["counters"] == {}
    assert snap["metrics"]["planner"] == {}


def test_disabled_overhead_under_two_percent():
    """The disabled hot path adds is_enabled() checks + null-span returns.
    Bound that cost structurally: (measured per-touch-point cost) × (a
    generous touch-point count) must stay under 2% of one instrumented
    eager spgemm_coo call on a smoke shape."""
    from repro.core import spgemm_coo
    a, b = _operands()
    f = lambda: jax.block_until_ready(
        spgemm_coo(a, b, out_cap=2048, accumulator="sort").val)
    f()                                           # compile/warm
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        f()
        times.append(time.perf_counter() - t0)
    t_call = sorted(times)[len(times) // 2]

    n_iter = 20_000
    t0 = time.perf_counter()
    for _ in range(n_iter):
        tr.is_enabled()
        tr.span("spgemm.accumulate")
        tr.sync(None)
    per_point = (time.perf_counter() - t0) / n_iter
    # 64 touch points per call is far above the real count (~10)
    assert 64 * per_point < 0.02 * t_call, (
        f"disabled obs overhead {64 * per_point * 1e6:.1f}us vs "
        f"2% of call = {0.02 * t_call * 1e6:.1f}us")


# ----------------------------------------------------------------- nesting


def test_enabled_spans_nest():
    obs.enable(reset=True)
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    evs = tr.get_tracer().spans()
    by_name = {e["name"]: e for e in evs}
    assert by_name["inner"]["parent"] == "outer"
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["parent"] is None
    assert by_name["outer"]["depth"] == 0
    # child interval inside parent interval
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts_us"] <= i["ts_us"]
    assert i["ts_us"] + i["dur_us"] <= o["ts_us"] + o["dur_us"] + 1e-6


def test_spans_nest_across_threads():
    obs.enable(reset=True)

    def work(tag):
        with tr.span(f"outer-{tag}"):
            with tr.span(f"inner-{tag}"):
                time.sleep(0.002)

    ts = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    evs = tr.get_tracer().spans()
    for i in range(2):
        inner = next(e for e in evs if e["name"] == f"inner-{i}")
        outer = next(e for e in evs if e["name"] == f"outer-{i}")
        assert inner["parent"] == f"outer-{i}"      # never the other thread's
        assert inner["depth"] == 1 and outer["depth"] == 0
        assert inner["tid"] == outer["tid"]
    tids = {e["tid"] for e in evs}
    assert len(tids) == 2


def test_spans_under_jit_are_flagged_and_fire_once():
    from functools import partial
    from repro.core import spgemm_coo
    obs.enable(reset=True)
    a, b = _operands()
    f = jax.jit(partial(spgemm_coo, out_cap=2048, accumulator="sort"))
    jax.block_until_ready(f(a, b).val)
    evs1 = tr.get_tracer().spans()
    traced = [e for e in evs1 if e["args"].get("traced")]
    assert traced, "trace-time spans must carry traced=True"
    # compiled repeat: instrumentation inside the jaxpr does not re-fire
    jax.block_until_ready(f(a, b).val)
    assert len(tr.get_tracer().spans()) == len(evs1)
    # span stack balanced after tracing
    assert tr._stack.get() == ()


# ------------------------------------------------------------------ export


def test_chrome_export_roundtrip(tmp_path):
    from repro.core import spgemm_coo
    from repro.plan import make_plan
    a, b = _operands()
    plan = make_plan(a, b)                # planner spans stay out of the trace
    obs.enable(reset=True)
    with tr.span("test.root"):
        jax.block_until_ready(spgemm_coo(a, b, out_cap=plan.out_cap,
                                         accumulator="sort", plan=plan).val)
    path = tmp_path / "trace.json"
    obs.export_chrome(str(path), extra={"metrics": mt.snapshot()})
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert evs and isinstance(evs, list)
    for e in evs:
        assert set(e) >= {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                          "args"}
        assert e["ts"] >= 0 and e["dur"] >= 0
    # the root span must enclose every other event recorded inside it
    root = next(e for e in evs if e["name"] == "test.root")
    for e in evs:
        if e is root:
            continue
        assert root["ts"] <= e["ts"] + 1e-6
        assert e["ts"] + e["dur"] <= root["ts"] + root["dur"] + 1e-6
    # span args carry backend + measured nnz, and the metrics merge survived
    acc = next(e for e in evs if e["name"] == "spgemm.accumulate")
    assert acc["args"]["backend"] == "sort"
    assert acc["args"]["nnz"] > 0
    assert "planner" in doc["metrics"]


def test_trace_args_never_carry_matrix_values():
    obs.enable(reset=True)
    v = jnp.asarray(np.array([3.14159, 2.71828], np.float32))
    with tr.span("s", data=v, n=4, tag="x"):
        pass
    (e,) = tr.get_tracer().spans()
    assert e["args"]["n"] == 4 and e["args"]["tag"] == "x"
    assert e["args"]["data"] == "<float32(2,)>"     # shape/dtype only


def test_metrics_snapshot_stable_across_identical_runs():
    from repro.core import spgemm_coo
    from repro.plan import make_plan

    def run():
        obs.enable(reset=True)
        a, b = _operands()
        plan = make_plan(a, b)
        jax.block_until_ready(spgemm_coo(a, b, out_cap=plan.out_cap,
                                         accumulator=plan.backend,
                                         plan=plan).val)
        snap = mt.snapshot()
        obs.disable()
        obs.reset()
        return snap

    s1, s2 = run(), run()
    assert s1["counters"] == s2["counters"]
    assert set(s1["planner"]) == set(s2["planner"])
    for k in s1["planner"]:
        assert s1["planner"][k]["backend"] == s2["planner"][k]["backend"]
        assert s1["planner"][k]["est"] == s2["planner"][k]["est"]


# ---------------------------------------------------------- poison/overflow


def test_overflow_event_increments_exactly_once_per_call():
    from repro.core import spgemm_coo
    from repro.core.accumulate import AccumulatorOverflow
    obs.enable(reset=True)
    a, b = _operands()
    for expected in (1, 2):
        with pytest.raises(AccumulatorOverflow):
            spgemm_coo(a, b, out_cap=4, accumulator="sort", check=True)
        assert mt.snapshot()["counters"]["spgemm.overflow_events"] == expected
    instants = [e for e in tr.get_tracer().snapshot()["events"]
                if e["name"] == "spgemm.overflow"]
    assert len(instants) == 2


def test_poison_event_increments_exactly_once_per_call():
    from repro.core.spgemm import accumulate_stream
    from repro.plan import Plan
    obs.enable(reset=True)
    rng = np.random.default_rng(3)
    n_rows = n_cols = 32
    m = 256
    row = jnp.asarray(rng.integers(0, n_rows, m), jnp.int32)
    col = jnp.asarray(rng.integers(0, n_cols, m), jnp.int32)
    val = jnp.asarray(rng.standard_normal(m), jnp.float32)
    # one 8-slot table for ~hundreds of distinct keys: guaranteed drops
    plan = Plan(backend="hash", out_cap=1024, n_blocks=1, block_cap=8,
                max_probes=2)
    for expected in (1, 2):
        coo = accumulate_stream(row, col, val, 1024, n_rows, n_cols,
                                backend="hash", plan=plan)
        assert int(coo.ngroups) > 1024              # poisoned past cap
        assert mt.snapshot()["counters"]["spgemm.poison_events"] == expected


def test_numeric_miss_poison_event_exactly_once_per_call():
    """A stale structure (validate=False) makes the numeric phase drop the
    unknown products into the overflow slot: one poison counter increment
    and one instant per call, never per miss."""
    from repro.core.spgemm import spgemm_coo_numeric
    from repro.plan import make_structure
    a1, b1 = _operands(dens=0.05, seed=1)
    st = make_structure(a1, b1)
    a2, b2 = _operands(dens=0.3, seed=2)
    obs.enable(reset=True)
    for expected in (1, 2):
        coo = spgemm_coo_numeric(a2, b2, st, validate=False)
        assert int(coo.ngroups) > st.out_cap        # poisoned past cap
        assert mt.snapshot()["counters"]["spgemm.poison_events"] == expected
    instants = [e for e in tr.get_tracer().snapshot()["events"]
                if e["name"] == "spgemm.poison"]
    assert len(instants) == 2


# ------------------------------------------------------------- cache/serve


def test_structure_cache_stats_snapshot():
    from repro.plan import StructureCache
    a, b = _operands()
    cache = StructureCache(capacity=4)
    cache.get(a, b)
    cache.get(a, b)
    s = cache.stats()
    assert s["misses"] == 1 and s["hits"] == 1 and s["size"] == 1
    s["hits"] = 999                                  # a copy, not a view
    assert cache.stats()["hits"] == 1


def test_engine_stats_dict_and_callable():
    from repro.serve import ServeConfig, ServingEngine
    cfg = ServeConfig(max_batch=2, max_new_tokens=4, s_max=16, eos_id=2)
    vocab = 8

    class _Stub:
        def prefill(self, params, batch, s_max):
            bsz = batch["tokens"].shape[0]
            return jnp.zeros((bsz, vocab)).at[:, 3].set(5.0), {}

        def decode_step(self, params, cache, tokens):
            bsz = tokens.shape[0]
            return jnp.zeros((bsz, vocab)).at[:, cfg.eos_id].set(5.0), cache

    eng = ServingEngine(_Stub(), {}, cfg)
    outs = eng.generate_batch([np.array([3, 4], np.int32)])
    assert eng.stats["tokens"] == sum(len(o) for o in outs)   # dict access
    snap = eng.stats()                                        # callable
    assert snap["requests"] == 1
    assert 0.0 <= snap["batch_occupancy"] <= 1.0
    assert snap["queue_s_per_request"] >= 0.0
    assert snap["compute_s_per_request"] > 0.0
    assert "hits" in snap["structure_cache"]


# ---------------------------------------------------------------- roofline


def test_roofline_fractions_in_gate_range():
    from repro.obs import roofline as rl
    a, b = _operands()
    res = rl.measure_roofline(a, b, backends=("sort", "stream"), iters=1)
    assert set(res) == {"sort", "stream"}
    for r in res.values():
        assert 0.0 < r["frac"] <= 1.5
        assert r["modeled_bytes"] > 0 and r["us"] > 0
    assert not obs.is_enabled()                     # tracer state restored
