"""SPLIM SpGEMM vs dense oracle; sorted-COO contract; complexity claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ell_cols_from_dense, ell_rows_from_dense, spgemm_coo,
                        spgemm_dense, spgemm_from_dense, spgemm_streaming,
                        spmm_ell_dense)
from repro.core.sccp import count_products, sccp_multiply

from conftest import random_sparse


def _pair(rng, n=32, density=0.2):
    a = random_sparse(rng, n, n, density)
    b = random_sparse(rng, n, n, density)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    return (a, b,
            ell_rows_from_dense(jnp.array(a), ka),
            ell_cols_from_dense(jnp.array(b), kb))


def test_spgemm_dense_matches_oracle(rng):
    a, b, ea, eb = _pair(rng)
    np.testing.assert_allclose(np.asarray(spgemm_dense(ea, eb)), a @ b,
                               atol=1e-4)


def test_spgemm_streaming_matches(rng):
    a, b, ea, eb = _pair(rng)
    np.testing.assert_allclose(np.asarray(spgemm_streaming(ea, eb)), a @ b,
                               atol=1e-4)


def test_spgemm_coo_sorted_unique(rng):
    a, b, ea, eb = _pair(rng)
    coo = spgemm_coo(ea, eb, out_cap=32 * 32)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-4)
    r = np.asarray(coo.row)
    c = np.asarray(coo.col)
    m = r >= 0
    keys = r[m].astype(np.int64) * 32 + c[m]
    assert (np.diff(keys) > 0).all(), "output must be sorted & duplicate-free"


def test_spgemm_jit_from_dense(rng):
    a, b, _, _ = _pair(rng, n=24)
    coo = spgemm_from_dense(jnp.array(a), jnp.array(b), 24, 24, 24 * 24)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-4)


def test_a_at_paper_kernel(rng):
    """The paper's benchmark kernel is C = A·Aᵀ."""
    a = random_sparse(rng, 40, 40, 0.15)
    at = a.T.copy()
    ea = ell_rows_from_dense(jnp.array(a), max(1, int((a != 0).sum(0).max())))
    eb = ell_cols_from_dense(jnp.array(at), max(1, int((at != 0).sum(1).max())))
    np.testing.assert_allclose(np.asarray(spgemm_dense(ea, eb)), a @ at,
                               atol=1e-4)


def test_complexity_counts(rng):
    """§III-C: SCCP performs NK² scalar products (vs N³ decompressed)."""
    n = 30
    a = random_sparse(rng, n, n, 0.2)
    b = random_sparse(rng, n, n, 0.2)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    eb = ell_cols_from_dense(jnp.array(b), kb)
    valid = int(count_products(ea, eb))
    exact = int(sum((a[:, c] != 0).sum() * (b[c, :] != 0).sum()
                    for c in range(n)))
    assert valid == exact
    assert valid <= n * ka * kb          # ≤ NK² (padding only reduces)
    assert valid < n ** 3                # strictly better than decompressed


def test_sccp_invalid_lanes_masked(rng):
    a, b, ea, eb = _pair(rng, n=16, density=0.3)
    val, row, col = sccp_multiply(ea, eb)
    val, row, col = map(np.asarray, (val, row, col))
    bad = (row < 0) | (col < 0)
    assert (val[bad] == 0).all()
    assert ((row >= 0) == (col >= 0)).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 32), density=st.floats(0.05, 0.5),
       seed=st.integers(0, 2 ** 16))
def test_spgemm_property(n, density, seed):
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, n, n, density)
    b = random_sparse(rng, n, n, density)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    eb = ell_cols_from_dense(jnp.array(b), kb)
    np.testing.assert_allclose(np.asarray(spgemm_dense(ea, eb)), a @ b,
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 40), d=st.integers(1, 24),
       density=st.floats(0.05, 0.5), seed=st.integers(0, 2 ** 16))
def test_spmm_property(n, d, density, seed):
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, n, n, density)
    x = rng.standard_normal((n, d)).astype(np.float32)
    ka = max(1, int((a != 0).sum(0).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    np.testing.assert_allclose(np.asarray(spmm_ell_dense(ea, jnp.array(x))),
                               a @ x, atol=1e-3)
