"""SPLIM SpGEMM vs dense oracle; sorted-COO contract; complexity claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import (ell_cols_from_dense, ell_rows_from_dense, spgemm_coo,
                        spgemm_dense, spgemm_from_dense, spgemm_streaming,
                        spmm_ell_dense)
from repro.core.sccp import count_products, sccp_multiply

from conftest import random_sparse


def _pair(rng, n=32, density=0.2):
    a = random_sparse(rng, n, n, density)
    b = random_sparse(rng, n, n, density)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    return (a, b,
            ell_rows_from_dense(jnp.array(a), ka),
            ell_cols_from_dense(jnp.array(b), kb))


def test_spgemm_dense_matches_oracle(rng):
    a, b, ea, eb = _pair(rng)
    np.testing.assert_allclose(np.asarray(spgemm_dense(ea, eb)), a @ b,
                               atol=1e-4)


def test_spgemm_streaming_matches(rng):
    a, b, ea, eb = _pair(rng)
    np.testing.assert_allclose(np.asarray(spgemm_streaming(ea, eb)), a @ b,
                               atol=1e-4)


def test_spgemm_coo_sorted_unique(rng):
    a, b, ea, eb = _pair(rng)
    coo = spgemm_coo(ea, eb, out_cap=32 * 32)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-4)
    r = np.asarray(coo.row)
    c = np.asarray(coo.col)
    m = r >= 0
    keys = r[m].astype(np.int64) * 32 + c[m]
    assert (np.diff(keys) > 0).all(), "output must be sorted & duplicate-free"


def test_spgemm_jit_from_dense(rng):
    a, b, _, _ = _pair(rng, n=24)
    coo = spgemm_from_dense(jnp.array(a), jnp.array(b), 24, 24, 24 * 24)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-4)


def test_a_at_paper_kernel(rng):
    """The paper's benchmark kernel is C = A·Aᵀ."""
    a = random_sparse(rng, 40, 40, 0.15)
    at = a.T.copy()
    ea = ell_rows_from_dense(jnp.array(a), max(1, int((a != 0).sum(0).max())))
    eb = ell_cols_from_dense(jnp.array(at), max(1, int((at != 0).sum(1).max())))
    np.testing.assert_allclose(np.asarray(spgemm_dense(ea, eb)), a @ at,
                               atol=1e-4)


def test_complexity_counts(rng):
    """§III-C: SCCP performs NK² scalar products (vs N³ decompressed)."""
    n = 30
    a = random_sparse(rng, n, n, 0.2)
    b = random_sparse(rng, n, n, 0.2)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    eb = ell_cols_from_dense(jnp.array(b), kb)
    valid = int(count_products(ea, eb))
    exact = int(sum((a[:, c] != 0).sum() * (b[c, :] != 0).sum()
                    for c in range(n)))
    assert valid == exact
    assert valid <= n * ka * kb          # ≤ NK² (padding only reduces)
    assert valid < n ** 3                # strictly better than decompressed


def test_sccp_invalid_lanes_masked(rng):
    a, b, ea, eb = _pair(rng, n=16, density=0.3)
    val, row, col = sccp_multiply(ea, eb)
    val, row, col = map(np.asarray, (val, row, col))
    bad = (row < 0) | (col < 0)
    assert (val[bad] == 0).all()
    assert ((row >= 0) == (col >= 0)).all()


def test_spgemm_tiled_accumulator_matches_sort(rng):
    """The multi-tile merge-tree accumulator yields the identical sorted COO."""
    from repro.core import spgemm_coo
    a, b, ea, eb = _pair(rng)
    c_sort = spgemm_coo(ea, eb, out_cap=32 * 32)
    c_tile = spgemm_coo(ea, eb, out_cap=32 * 32, accumulator="tiled", tile=128)
    np.testing.assert_array_equal(np.asarray(c_sort.row), np.asarray(c_tile.row))
    np.testing.assert_array_equal(np.asarray(c_sort.col), np.asarray(c_tile.col))
    np.testing.assert_allclose(np.asarray(c_sort.val), np.asarray(c_tile.val),
                               atol=1e-5)
    assert int(c_sort.ngroups) == int(c_tile.ngroups)


@pytest.mark.parametrize("accumulator", ["sort", "tiled"])
def test_spgemm_batched_vmap(rng, accumulator):
    """spgemm_coo_batched/spgemm_dense_batched vmap over a leading batch."""
    from repro.core import spgemm_coo_batched, spgemm_dense_batched
    n, batch = 24, 3
    As = np.stack([random_sparse(np.random.default_rng(s), n, n, 0.2)
                   for s in range(batch)])
    Bs = np.stack([random_sparse(np.random.default_rng(s + 50), n, n, 0.2)
                   for s in range(batch)])
    ka = max(1, int(max((As[i] != 0).sum(0).max() for i in range(batch))))
    kb = max(1, int(max((Bs[i] != 0).sum(1).max() for i in range(batch))))
    ea = jax.vmap(lambda x: ell_rows_from_dense(x, ka))(jnp.asarray(As))
    eb = jax.vmap(lambda x: ell_cols_from_dense(x, kb))(jnp.asarray(Bs))
    coo = spgemm_coo_batched(ea, eb, n * n, accumulator=accumulator, tile=256)
    dense = spgemm_dense_batched(ea, eb)
    for i in range(batch):
        ci = jax.tree.map(lambda leaf: leaf[i], coo)
        np.testing.assert_allclose(np.asarray(ci.to_dense()), As[i] @ Bs[i],
                                   atol=1e-4)
    np.testing.assert_allclose(np.asarray(dense), As @ Bs, atol=1e-4)
    assert coo.ngroups.shape == (batch,)


def test_spgemm_tiled_out_cap_exceeds_stream(rng):
    """Regression: tiled accumulator must accept out_cap larger than the
    padded product stream (generous upper bounds on small inputs)."""
    from repro.core import spgemm_coo
    a, b, ea, eb = _pair(rng, n=8, density=0.3)
    # stream = k_a*8*k_b « out_cap
    c_tile = spgemm_coo(ea, eb, out_cap=4096, accumulator="tiled", tile=64)
    c_sort = spgemm_coo(ea, eb, out_cap=4096)
    np.testing.assert_allclose(np.asarray(c_tile.to_dense()), a @ b, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c_sort.row), np.asarray(c_tile.row))
    assert int(c_tile.ngroups) == int(c_sort.ngroups)


def test_check_no_overflow_batched(rng):
    """check_no_overflow handles batched Coo (per-batch ngroups)."""
    from repro.core import (check_no_overflow, AccumulatorOverflow,
                            spgemm_coo_batched)
    n, batch = 16, 2
    As = np.stack([random_sparse(np.random.default_rng(s), n, n, 0.4)
                   for s in range(batch)])
    Bs = np.stack([random_sparse(np.random.default_rng(s + 9), n, n, 0.4)
                   for s in range(batch)])
    ka = max(1, int(max((As[i] != 0).sum(0).max() for i in range(batch))))
    kb = max(1, int(max((Bs[i] != 0).sum(1).max() for i in range(batch))))
    ea = jax.vmap(lambda x: ell_rows_from_dense(x, ka))(jnp.asarray(As))
    eb = jax.vmap(lambda x: ell_cols_from_dense(x, kb))(jnp.asarray(Bs))
    ok = check_no_overflow(spgemm_coo_batched(ea, eb, n * n))
    assert not bool(ok.overflowed().any())
    with pytest.raises(AccumulatorOverflow):
        check_no_overflow(spgemm_coo_batched(ea, eb, 4))


def test_merge_sorted_overflow_detected():
    """Regression: out_cap truncation must be detectable, not silent."""
    from repro.core import AccumulatorOverflow, accumulate_checked
    from repro.core.accumulate import accumulate
    row = jnp.asarray([0, 0, 1, 2, 3], jnp.int32)
    col = jnp.asarray([0, 1, 0, 2, 3], jnp.int32)
    val = jnp.ones(5, jnp.float32)
    # 5 unique coords, cap 3: truncated, but ngroups carries the truth
    coo = accumulate(row, col, val, 3, 4, 4)
    assert int(coo.ngroups) == 5
    assert bool(coo.overflowed())
    with pytest.raises(AccumulatorOverflow):
        accumulate_checked(row, col, val, 3, 4, 4)
    # ample capacity: same call sites report clean
    ok = accumulate_checked(row, col, val, 8, 4, 4)
    assert int(ok.ngroups) == 5 and not bool(ok.overflowed())
    np.testing.assert_allclose(np.asarray(ok.to_dense()).sum(), 5.0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(6, 32), density=st.floats(0.05, 0.5),
       seed=st.integers(0, 2 ** 16))
def test_spgemm_property(n, density, seed):
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, n, n, density)
    b = random_sparse(rng, n, n, density)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    eb = ell_cols_from_dense(jnp.array(b), kb)
    np.testing.assert_allclose(np.asarray(spgemm_dense(ea, eb)), a @ b,
                               atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(8, 40), d=st.integers(1, 24),
       density=st.floats(0.05, 0.5), seed=st.integers(0, 2 ** 16))
def test_spmm_property(n, d, density, seed):
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, n, n, density)
    x = rng.standard_normal((n, d)).astype(np.float32)
    ka = max(1, int((a != 0).sum(0).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    np.testing.assert_allclose(np.asarray(spmm_ell_dense(ea, jnp.array(x))),
                               a @ x, atol=1e-3)
