"""SparseLinear: pruned-ELLPACK weights match masked-dense matmul."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.sparse import (magnitude_prune, sparse_linear_apply,
                                 sparsify_linear)


def test_magnitude_prune_fraction():
    w = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    wp = magnitude_prune(w, 0.9)
    frac = float((wp != 0).sum()) / w.size
    assert 0.08 <= frac <= 0.12


def test_sparse_linear_matches_pruned_dense():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((48, 32)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 7, 48)), jnp.float32)
    wp = magnitude_prune(w, 0.8)
    w_ell = sparsify_linear(w, 0.8)
    got = sparse_linear_apply(x, w_ell)
    # ELLPACK may additionally drop overflow rows beyond the hybrid width k;
    # reconstruct the actually-stored weight for an exact oracle
    w_stored = w_ell.to_dense()
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w_stored),
                               atol=1e-4)
    # stored weight is a subset of the pruned weight
    mask_lost = np.asarray((w_stored == 0) & (wp != 0))
    assert mask_lost.mean() < 0.25


def test_sparse_linear_jit():
    rng = np.random.default_rng(2)
    w_ell = sparsify_linear(
        jnp.asarray(rng.standard_normal((32, 32)), jnp.float32), 0.7)
    f = jax.jit(lambda x: sparse_linear_apply(x, w_ell))
    out = f(jnp.ones((3, 32)))
    assert np.isfinite(np.asarray(out)).all()
