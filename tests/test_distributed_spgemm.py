"""Sparse-native distributed SpGEMM on 8 fake host devices.

Multi-host-shaped property tests: ``spgemm_coo_sharded`` must be
*bit-identical* to single-device ``spgemm_coo`` — same sorted coordinate
stream, same padding, same ``ngroups`` — for all three schedules (1D
``ring``/``cstat`` and the 2D ``summa`` grid). Test matrices
carry small-integer values so every partial sum is exact in float32 and the
bit-exact comparison is order-independent (the distributed path sums each
output group in two stages).

The ``summa`` tests honor ``REPRO_SUMMA_GRID`` (e.g. ``"2x4"``, ``"1x8"``;
CI's fake-8-device job matrixes over both) to pin the logical grid — a
``1x8`` run exercises the degenerate-grid path end to end.

All snippets run subprocess-isolated (jax pins the device count at first
init) via ``conftest.run_with_devices``.
"""
from conftest import run_with_devices

_PRELUDE = """
import warnings; warnings.filterwarnings("ignore")
import dataclasses, os
import numpy as np, jax, jax.numpy as jnp
from repro.core import (ell_rows_from_dense, ell_cols_from_dense, spgemm_coo,
                        spgemm_coo_sharded, AccumulatorOverflow)
from repro.plan import make_dist_plan

mesh = jax.make_mesh((8,), ("ring",))
rng = np.random.default_rng(0)

def env_grid():
    pr, pc = os.environ.get("REPRO_SUMMA_GRID", "2x4").split("x")
    return int(pr), int(pc)

def with_grid(dp, sched):
    # pin the summa grid from the CI matrix (identity for 1D schedules)
    if sched != "summa":
        return dataclasses.replace(dp, schedule=sched)
    pr, pc = env_grid()
    return dataclasses.replace(dp, schedule=sched, pr=pr, pc=pc)

def int_sparse(m, n, density, lo=-4, hi=5):
    # small-integer values: float32 sums are exact, so bit-equality holds
    # regardless of the distributed summation order
    return (((rng.random((m, n)) < density)
             * rng.integers(lo, hi, (m, n))).astype(np.float32))

def assert_bit_identical(got, ref):
    assert got.cap == ref.cap, (got.cap, ref.cap)
    np.testing.assert_array_equal(np.asarray(got.row), np.asarray(ref.row))
    np.testing.assert_array_equal(np.asarray(got.col), np.asarray(ref.col))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(ref.val))
    assert int(got.ngroups) == int(ref.ngroups)
"""


def test_sharded_matches_single_device_square():
    run_with_devices(_PRELUDE + """
A, B = int_sparse(32, 32, 0.25), int_sparse(32, 32, 0.25)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
ref = spgemm_coo(a, b, out_cap="auto")
for sched in ("ring", "cstat", "summa"):
    got = spgemm_coo_sharded(a, b, mesh, "ring", schedule=sched, check=True)
    assert_bit_identical(got, ref)
    np.testing.assert_allclose(np.asarray(got.to_dense()), A @ B, atol=1e-4)
    # a prebuilt DistPlan keeps the whole engine jit-compatible
    dp = make_dist_plan(a, b, n_dev=8, schedule=sched)
    got_j = jax.jit(lambda x, y: spgemm_coo_sharded(
        x, y, mesh, "ring", dist_plan=dp))(a, b)
    assert_bit_identical(got_j, ref)
print("OK")
""", timeout=600)


def test_sharded_rectangular_nondivisible_slabs():
    """k_a=5, k_b=3 don't divide the 8-ring: exercises INVALID slab padding
    (the old ring_spgemm failed here with an opaque reshape error)."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(24, 32, 0.2), int_sparse(32, 40, 0.2)
a = ell_rows_from_dense(jnp.array(A), 5)
b = ell_cols_from_dense(jnp.array(B), 3)
ref = spgemm_coo(a, b, out_cap="auto")
for sched in ("ring", "cstat", "summa"):
    got = spgemm_coo_sharded(a, b, mesh, "ring", schedule=sched, check=True)
    assert_bit_identical(got, ref)
print("OK")
""", timeout=600)


def test_sharded_skewed_rows():
    """Skewed row distribution: a few hot output rows stress the per-owner
    block/bin capacities (exact histograms must still never drop)."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(64, 64, 0.05), int_sparse(64, 64, 0.08)
hot = rng.choice(64, 8, replace=False)
A[hot] = ((rng.random((8, 64)) < 0.6) * rng.integers(-4, 5, (8, 64))).astype(np.float32)
ka = max(1, int((A != 0).sum(0).max()))
kb = max(1, int((B != 0).sum(1).max()))
a = ell_rows_from_dense(jnp.array(A), ka)
b = ell_cols_from_dense(jnp.array(B), kb)
ref = spgemm_coo(a, b, out_cap="auto")
for sched in ("ring", "cstat", "summa"):
    got = spgemm_coo_sharded(a, b, mesh, "ring", schedule=sched, check=True)
    assert_bit_identical(got, ref)
print("OK")
""", timeout=600)


def test_sharded_empty_and_tiny():
    """All-zero operands and fewer rows than devices both stay exact."""
    run_with_devices(_PRELUDE + """
Z = np.zeros((16, 16), np.float32)
az = ell_rows_from_dense(jnp.array(Z), 2)
bz = ell_cols_from_dense(jnp.array(Z), 2)
refz = spgemm_coo(az, bz, out_cap="auto")
for sched in ("ring", "cstat", "summa"):
    got = spgemm_coo_sharded(az, bz, mesh, "ring", schedule=sched, check=True)
    assert_bit_identical(got, refz)
    assert int(got.nnz()) == 0
A, B = int_sparse(5, 6, 0.5), int_sparse(6, 7, 0.5)   # n_rows < n_dev
a = ell_rows_from_dense(jnp.array(A), 5)
b = ell_cols_from_dense(jnp.array(B), 6)
ref = spgemm_coo(a, b, out_cap="auto")
for sched in ("ring", "cstat", "summa"):
    got = spgemm_coo_sharded(a, b, mesh, "ring", schedule=sched, check=True)
    assert_bit_identical(got, ref)
print("OK")
""")


def test_sharded_planned_backends():
    """Every accumulation backend runs device-local inside the ring and
    still reproduces the single-device stream bit-exactly. 'stream' is the
    special one: accumulation happens *inside* the ring scan, so the
    stacked n_dev-step product stream is never materialized per device."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(32, 32, 0.25), int_sparse(32, 32, 0.25)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
ref = spgemm_coo(a, b, out_cap="auto")
for backend in ("sort", "tiled", "bucket", "hash", "stream", "search"):
    for sched in ("ring", "cstat", "summa"):
        got = spgemm_coo_sharded(a, b, mesh, "ring", accumulator=backend,
                                 schedule=sched, check=True)
        assert_bit_identical(got, ref)
print("OK")
""", timeout=600)


def test_sharded_stream_backend_planned():
    """The streaming accumulator under a prebuilt DistPlan (jit-compatible)
    stays bit-identical, and skewed rows don't break its device-local
    buffers (exact per-shard histograms size local/block caps)."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(64, 64, 0.08), int_sparse(64, 64, 0.08)
hot = rng.choice(64, 6, replace=False)
A[hot] = ((rng.random((6, 64)) < 0.5) * rng.integers(-4, 5, (6, 64))).astype(np.float32)
ka = max(1, int((A != 0).sum(0).max()))
kb = max(1, int((B != 0).sum(1).max()))
a = ell_rows_from_dense(jnp.array(A), ka)
b = ell_cols_from_dense(jnp.array(B), kb)
ref = spgemm_coo(a, b, out_cap="auto")
for sched in ("ring", "cstat", "summa"):
    dp = make_dist_plan(a, b, n_dev=8, schedule=sched, backend="stream")
    assert dp.base.backend == "stream"
    got = jax.jit(lambda x, y: spgemm_coo_sharded(
        x, y, mesh, "ring", dist_plan=dp))(a, b)
    assert_bit_identical(got, ref)
print("OK")
""", timeout=600)


def test_sharded_batched():
    run_with_devices(_PRELUDE + """
from repro.core import spgemm_coo_sharded_batched
from repro.core.formats import EllRows, EllCols
n, bsz = 32, 3
As = np.stack([int_sparse(n, n, 0.2) for _ in range(bsz)])
Bs = np.stack([int_sparse(n, n, 0.2) for _ in range(bsz)])
als = [ell_rows_from_dense(jnp.array(As[i]), 12) for i in range(bsz)]
bls = [ell_cols_from_dense(jnp.array(Bs[i]), 12) for i in range(bsz)]
ab = EllRows(val=jnp.stack([x.val for x in als]),
             idx=jnp.stack([x.idx for x in als]), n_rows=n)
bb = EllCols(val=jnp.stack([x.val for x in bls]),
             idx=jnp.stack([x.idx for x in bls]), n_cols=n)
dp = make_dist_plan(als[0], bls[0], n_dev=8, slack=2.0)
for sched in ("ring", "cstat", "summa"):
    dps = with_grid(dp, sched)
    got = spgemm_coo_sharded_batched(ab, bb, mesh, "ring", dist_plan=dps,
                                     check=True)
    assert got.row.shape[0] == bsz and got.ngroups.shape == (bsz,)
    for i in range(bsz):
        ref = spgemm_coo(als[i], bls[i], out_cap=dp.out_cap)
        np.testing.assert_array_equal(np.asarray(got.row[i]), np.asarray(ref.row))
        np.testing.assert_array_equal(np.asarray(got.val[i]), np.asarray(ref.val))
print("OK")
""", timeout=600)


def test_overflow_poisoning_crosses_collective():
    """An undersized per-owner block truncates on *some* device; the psum'd
    poison must surface in the replicated result and make check raise."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(32, 32, 0.25), int_sparse(32, 32, 0.25)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
for sched in ("ring", "cstat", "summa"):
    tiny = dataclasses.replace(with_grid(make_dist_plan(a, b, n_dev=8), sched),
                               block_cap=2, bin_cap=2)
    got = spgemm_coo_sharded(a, b, mesh, "ring", dist_plan=tiny)
    assert bool(got.overflowed()), int(got.ngroups)
    try:
        spgemm_coo_sharded(a, b, mesh, "ring", dist_plan=tiny, check=True)
        raise SystemExit("check=True should have raised")
    except AccumulatorOverflow:
        pass
print("OK")
""")


def test_ring_spgemm_pads_nondivisible_slabs():
    """Satellite fix: the dense-baseline ring pads instead of failing."""
    run_with_devices(_PRELUDE + """
from repro.core.distributed import ring_spgemm
A, B = int_sparse(24, 32, 0.2), int_sparse(32, 40, 0.2)
a = ell_rows_from_dense(jnp.array(A), 5)     # 5 % 8 != 0 (truncating k is
b = ell_cols_from_dense(jnp.array(B), 3)     # fine: compare vs to_dense)
C = ring_spgemm(a, b, mesh, "ring")
ref = np.asarray(a.to_dense()) @ np.asarray(b.to_dense())
np.testing.assert_allclose(np.asarray(C), ref, atol=1e-4)
print("OK")
""")


def test_put_spgemm_operands_presharded():
    """Pre-sharded operands (parallel.sharding.put_spgemm_operands) feed the
    engine without changing results."""
    run_with_devices(_PRELUDE + """
from repro.parallel.sharding import put_spgemm_operands
A, B = int_sparse(32, 32, 0.25), int_sparse(32, 32, 0.25)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
ref = spgemm_coo(a, b, out_cap="auto")
dp = make_dist_plan(a, b, n_dev=8, schedule="ring")
ash, bsh = put_spgemm_operands(a, b, mesh, "ring", schedule="ring")
got = spgemm_coo_sharded(ash, bsh, mesh, "ring", dist_plan=dp, check=True)
assert_bit_identical(got, ref)
print("OK")
""")


def test_facade_parity_sharded_paths():
    """repro.spgemm(mesh=, axis=) must be bit-identical to the legacy
    spgemm_coo_sharded / _sharded_numeric wrappers it routes to."""
    run_with_devices(_PRELUDE + """
import repro
from repro.core.distributed import spgemm_coo_sharded_numeric
from repro.plan import make_structure

A, B = int_sparse(32, 32, 0.25), int_sparse(32, 32, 0.25)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
for sched in ("ring", "cstat", "summa"):
    ref = spgemm_coo_sharded(a, b, mesh, "ring", schedule=sched, check=True)
    got = repro.spgemm(a, b, mesh=mesh, axis="ring", schedule=sched,
                       check=True)
    assert_bit_identical(got, ref)

st = make_structure(a, b, n_dev=8)
ref_n = spgemm_coo_sharded_numeric(a, b, mesh, "ring", st)
got_n = repro.spgemm(a, b, mesh=mesh, axis="ring", structure=st)
assert_bit_identical(got_n, ref_n)
print("OK")
""", timeout=600)

def test_summa_nonsquare_grids():
    """Both 8-device factorizations (2×4, 4×2) plus the CI-matrixed grid
    stay bit-identical with overlap on and off — the logical grid is index
    arithmetic over the same flat slab sharding, so the factorization can
    only change communication, never the result."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(40, 32, 0.2), int_sparse(32, 48, 0.2)
a = ell_rows_from_dense(jnp.array(A), 7)
b = ell_cols_from_dense(jnp.array(B), 5)
ref = spgemm_coo(a, b, out_cap="auto")
dp = make_dist_plan(a, b, n_dev=8)
for pr, pc in ((2, 4), (4, 2), env_grid()):
    dps = dataclasses.replace(dp, schedule="summa", pr=pr, pc=pc)
    for overlap in (True, False):
        got = spgemm_coo_sharded(a, b, mesh, "ring", dist_plan=dps,
                                 overlap=overlap, check=True)
        assert_bit_identical(got, ref)
print("OK")
""", timeout=600)


def test_summa_warm_numeric_and_facade():
    """Warm numeric phase under schedule='summa' (and 'auto' reading the
    structure's cached 2D pick) reproduces the cold product exactly
    (small-int values ⇒ order-exact sums), overlap on/off identical; the
    facade threads schedule/overlap through, and 'cstat' — meaningless
    without a resident C block — is rejected."""
    run_with_devices(_PRELUDE + """
import repro
from repro.core.distributed import spgemm_coo_sharded_numeric
from repro.plan import make_structure
A, B = int_sparse(32, 32, 0.25), int_sparse(32, 32, 0.25)
a = ell_rows_from_dense(jnp.array(A), 16)
b = ell_cols_from_dense(jnp.array(B), 16)
ref = spgemm_coo(a, b, out_cap="auto")
st = make_structure(a, b, n_dev=8, schedules=("summa", "ring"))
for sched in ("auto", "ring", "summa"):
    for overlap in (True, False):
        got = spgemm_coo_sharded_numeric(a, b, mesh, "ring", st,
                                         schedule=sched, overlap=overlap,
                                         check=True)
        np.testing.assert_array_equal(np.asarray(got.to_dense()), A @ B)
        assert int(got.ngroups) == int(ref.ngroups)
got_f = repro.spgemm(a, b, mesh=mesh, axis="ring", structure=st,
                     schedule="summa", overlap=False, check=True)
np.testing.assert_array_equal(np.asarray(got_f.to_dense()), A @ B)
try:
    spgemm_coo_sharded_numeric(a, b, mesh, "ring", st, schedule="cstat")
    raise SystemExit("cstat should be rejected on the numeric path")
except ValueError:
    pass
print("OK")
""", timeout=600)


def test_summa_poison_crosses_grid_axes():
    """Truncation inside individual grid cells must poison the replicated
    result: the overflow psum runs over the full flat axis, so a drop at any
    (row, column) coordinate of the logical grid surfaces on every device —
    under both factorizations and their transposes."""
    run_with_devices(_PRELUDE + """
A, B = int_sparse(32, 32, 0.5), int_sparse(32, 32, 0.5)
a = ell_rows_from_dense(jnp.array(A), 20)
b = ell_cols_from_dense(jnp.array(B), 20)
dp = make_dist_plan(a, b, n_dev=8, schedule="summa")
got_ok = spgemm_coo_sharded(a, b, mesh, "ring", dist_plan=dp, check=True)
assert not bool(got_ok.overflowed())
for pr, pc in ((2, 4), (4, 2)):
    tiny = dataclasses.replace(dp, pr=pr, pc=pc, local_cap=128)
    got = spgemm_coo_sharded(a, b, mesh, "ring", dist_plan=tiny)
    assert bool(got.overflowed()), (pr, pc, int(got.ngroups))
    try:
        spgemm_coo_sharded(a, b, mesh, "ring", dist_plan=tiny, check=True)
        raise SystemExit("check=True should have raised")
    except AccumulatorOverflow:
        pass
print("OK")
""", timeout=600)
