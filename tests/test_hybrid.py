"""Hybrid ELL+COO SpGEMM vs dense oracle on adversarial skewed matrices,
and batched SpGEMM vs an explicit per-slice loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import ell_cols_from_dense, ell_rows_from_dense
from repro.core.hybrid import (ell_width_rule, hybrid_spgemm_dense,
                               split_cols_hybrid, split_rows_hybrid)

from conftest import random_sparse


def _skewed(rng, n, density, n_hot, hot_density):
    """Mostly-sparse matrix with a few near-dense rows AND columns — the
    exact workload the NNZ-a + σ hybrid rule exists for (power-law rows
    inflate the uniform ELLPACK width for everyone)."""
    a = random_sparse(rng, n, n, density)
    hot = rng.choice(n, size=max(1, n_hot), replace=False)
    a[hot] = (rng.standard_normal((len(hot), n))
              * (rng.random((len(hot), n)) < hot_density)).astype(np.float32)
    a[:, hot] = (rng.standard_normal((n, len(hot)))
                 * (rng.random((n, len(hot))) < hot_density)).astype(np.float32)
    return a


def _hybrid_pair(a, bt):
    n = a.shape[0]
    k_a = ell_width_rule((a != 0).sum(0))
    k_b = ell_width_rule((bt != 0).sum(1))
    coo_cap = int(max((a != 0).sum(), (bt != 0).sum()))  # ample overflow room
    ha = split_rows_hybrid(jnp.array(a), k_a, coo_cap=coo_cap)
    hb = split_cols_hybrid(jnp.array(bt), k_b, coo_cap=coo_cap)
    return ha, hb


def test_hybrid_split_lossless(rng):
    a = _skewed(rng, 48, 0.1, 5, 0.8)
    ha, _ = _hybrid_pair(a, a.T.copy())
    np.testing.assert_allclose(np.asarray(ha.to_dense()), a, atol=1e-6)
    # the trunk really is clipped: ELL alone must miss the hot rows
    assert np.abs(np.asarray(ha.ell.to_dense()) - a).max() > 0
    assert int(ha.coo.nnz()) > 0


def test_hybrid_matches_oracle_skewed(rng):
    a = _skewed(rng, 40, 0.15, 4, 0.9)
    b = _skewed(rng, 40, 0.15, 4, 0.9)
    ha, hb = _hybrid_pair(a, b)
    got = np.asarray(jax.jit(hybrid_spgemm_dense)(ha, hb))
    np.testing.assert_allclose(got, a @ b, atol=1e-3)


@settings(max_examples=12, deadline=None)
@given(n=st.integers(12, 48), density=st.floats(0.05, 0.3),
       n_hot=st.integers(1, 6), hot_density=st.floats(0.5, 1.0),
       seed=st.integers(0, 2 ** 16))
def test_hybrid_property_adversarial(n, density, n_hot, hot_density, seed):
    """Hybrid ELL+COO ≡ dense oracle across skew regimes (paper §III-C)."""
    rng = np.random.default_rng(seed)
    a = _skewed(rng, n, density, min(n_hot, n // 2), hot_density)
    b = _skewed(rng, n, density, min(n_hot, n // 2), hot_density)
    ha, hb = _hybrid_pair(a, b)
    np.testing.assert_allclose(np.asarray(hybrid_spgemm_dense(ha, hb)),
                               a @ b, atol=1e-3)


@settings(max_examples=6, deadline=None)
@given(batch=st.integers(2, 4), n=st.sampled_from([16, 24]),
       density=st.floats(0.1, 0.4), seed=st.integers(0, 2 ** 12),
       accumulator=st.sampled_from(["sort", "tiled", "bucket", "hash",
                                    "stream"]))
def test_spgemm_coo_batched_vs_per_slice_loop(batch, n, density, seed,
                                              accumulator):
    """Batched vmap ≡ an explicit Python loop of single-matrix calls, for
    every leaf including ngroups, on every backend."""
    from repro.core import spgemm_coo, spgemm_coo_batched
    rng = np.random.default_rng(seed)
    As = np.stack([random_sparse(np.random.default_rng(seed + i), n, n,
                                 density) for i in range(batch)])
    Bs = np.stack([random_sparse(np.random.default_rng(seed + 77 + i), n, n,
                                 density) for i in range(batch)])
    ka = max(1, int(max((As[i] != 0).sum(0).max() for i in range(batch))))
    kb = max(1, int(max((Bs[i] != 0).sum(1).max() for i in range(batch))))
    ea = jax.vmap(lambda x: ell_rows_from_dense(x, ka))(jnp.asarray(As))
    eb = jax.vmap(lambda x: ell_cols_from_dense(x, kb))(jnp.asarray(Bs))
    out_cap = n * n
    got = spgemm_coo_batched(ea, eb, out_cap, accumulator=accumulator,
                             tile=256, check=True)
    for i in range(batch):
        ei = ell_rows_from_dense(jnp.asarray(As[i]), ka)
        fi = ell_cols_from_dense(jnp.asarray(Bs[i]), kb)
        exp = spgemm_coo(ei, fi, out_cap, accumulator=accumulator, tile=256)
        gi = jax.tree.map(lambda l: l[i], got)
        np.testing.assert_array_equal(np.asarray(gi.row), np.asarray(exp.row))
        np.testing.assert_array_equal(np.asarray(gi.col), np.asarray(exp.col))
        np.testing.assert_allclose(np.asarray(gi.val), np.asarray(exp.val),
                                   atol=1e-5)
        assert int(gi.ngroups) == int(exp.ngroups)
        np.testing.assert_allclose(np.asarray(gi.to_dense()), As[i] @ Bs[i],
                                   atol=1e-4)
