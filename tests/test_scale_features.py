"""Large-scale-runnability features: elastic restore, long-context decode,
dry-run entry point, hwmodel properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from conftest import run_with_devices


def test_elastic_checkpoint_restore_new_sharding(tmp_path):
    """A checkpoint written unsharded restores onto a different mesh
    topology (elastic re-mesh after failures)."""
    run_with_devices(f"""
import warnings; warnings.filterwarnings("ignore")
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

mgr = CheckpointManager("{tmp_path}")
params = {{"w": jnp.arange(64.0).reshape(8, 8)}}
opt = {{"step": jnp.array(3, jnp.int32)}}
mgr.save(1, params, opt)

# restore onto a 4x2 mesh with the leaf sharded over 'a'
mesh = jax.make_mesh((4, 2), ("a", "b"))
sh = {{"w": NamedSharding(mesh, P("a", "b"))}}
osh = {{"step": NamedSharding(mesh, P())}}
p2, o2, _ = mgr.restore(1, params, opt, shardings=(sh, osh))
assert p2["w"].sharding == sh["w"], p2["w"].sharding
np.testing.assert_allclose(np.asarray(p2["w"]), np.arange(64.0).reshape(8,8))
print("OK")
""", n_devices=8)


def test_long_context_ring_decode_mamba_and_rg():
    """Decode far past the window/prefill length: O(1)-state paths stay
    finite and the ring cache wraps correctly."""
    from repro.configs import get_config
    from repro.models import build_model
    for arch in ("falcon-mamba-7b", "recurrentgemma-9b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 12),
                                              3, cfg.vocab)}
        logits, cache = model.prefill(params, batch, s_max=64)
        step = jax.jit(model.decode_step)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        # decode 3x the local-attention window (window=8 in reduced config)
        for _ in range(30):
            logits, cache = step(params, cache, tok)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        assert np.isfinite(np.asarray(logits)).all(), arch


def test_ring_cache_wraps_consistently():
    """After wrapping, ring-decode still matches a full forward pass."""
    from repro.configs import get_config
    from repro.models import build_model, transformer
    cfg = get_config("recurrentgemma-9b").reduced()   # window = 8
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    s = 24                                            # 3x window
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, s), 3, cfg.vocab)
    full_logits, _, _ = transformer.decoder_forward(params, toks, cfg)
    logits, cache = model.prefill(params, {"tokens": toks[:, :4]}, s_max=s + 2)
    for t in range(4, s):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits, np.float32),
            np.asarray(full_logits[:, t], np.float32), rtol=0.15, atol=0.05,
            err_msg=f"pos {t}")


def test_dryrun_entrypoint_single_cell(tmp_path):
    """The dry-run driver itself works end-to-end from a fresh process
    (cheapest cell: falcon-mamba long_500k, batch 1, decode)."""
    import os, subprocess, sys, json
    from pathlib import Path
    repo = Path(__file__).resolve().parents[1]
    # inherit the environment (like conftest.run_with_devices): dropping
    # e.g. JAX_PLATFORMS would make jax probe hardware plugins and hang
    env = dict(os.environ, PYTHONPATH=str(repo / "src"))
    env.pop("XLA_FLAGS", None)   # dryrun sets its own device count
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "falcon-mamba-7b", "--shape", "long_500k", "--single-pod-only",
         "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads((tmp_path / "falcon-mamba-7b__long_500k__pod16x16.json")
                     .read_text())
    assert rec["n_devices"] == 256
    assert rec["hlo_flops_tc"] > 0


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1_000, 200_000), k=st.integers(2, 200),
       pes=st.sampled_from([8, 16, 32]))
def test_hwmodel_monotonic(n, k, pes):
    """Latency grows with k and shrinks with PEs, for any matrix shape."""
    from repro.core import hwmodel
    s = hwmodel.MatrixStats(n=n, nnz_a=n * k // 2, nnz_b=n * k // 2,
                            k_a=k, k_b=k, valid_products=n * k * k // 4,
                            nnz_c=min(n * k, n * n), sigma=1.0)
    cfg = dataclasses.replace(hwmodel.SplimConfig(), n_pes=pes)
    lat = hwmodel.splim_latency(s, cfg)
    t = lat["total"]
    s2 = dataclasses.replace(s, k_a=k + 8, k_b=k + 8,
                             valid_products=int(s.valid_products * 1.2))
    assert hwmodel.splim_latency(s2, cfg)["total"] > t
    # more PEs speed up the compute/merge terms; the ring term (2T RowClones)
    # legitimately *grows* with T, so compare totals net of ring — tiny
    # matrices can be ring-dominated (over-parallelization, physically real)
    cfg2 = dataclasses.replace(cfg, n_pes=pes * 2)
    lat2 = hwmodel.splim_latency(s, cfg2)
    assert (lat2["total"] - lat2["ring"]) < (t - lat["ring"])
    assert hwmodel.splim_energy(s, cfg)["total"] > 0
