"""Minimal fixed-seed fallback for ``hypothesis`` (offline environments).

The tier-1 suite must collect and run without network access, and the
container may not ship ``hypothesis``. Test modules import through:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

This shim implements just the surface those tests use — ``given`` with
keyword strategies, ``settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``sampled_from`` strategies — drawing examples
from a fixed-seed ``random.Random`` so runs are reproducible. It does no
shrinking and no database; it is a deterministic example sweep, not a
replacement for real hypothesis (install the ``test`` extra for that).
"""
from __future__ import annotations

import random

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        choices = list(elements)
        return _Strategy(lambda rng: rng.choice(choices))


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Decorator recording example-count config on the (wrapped) test."""
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    """Decorator: run the test over fixed-seed draws of every strategy.

    The wrapper takes no parameters so pytest doesn't mistake strategy
    names for fixtures (mirrors hypothesis' own signature rewriting).
    """
    def deco(fn):
        def wrapper():
            max_examples = getattr(wrapper, "_propcheck_max_examples",
                                   _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for i in range(max_examples):
                kwargs = {name: s.example(rng)
                          for name, s in strategy_kwargs.items()}
                try:
                    fn(**kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i}: {kwargs!r}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        return wrapper
    return deco
