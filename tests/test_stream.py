"""Streaming fused SpGEMM backend: slab-scan multiply→compact→merge.

The ``'stream'`` accumulator (core/streaming.py) must reproduce the
``'sort'`` backend's sorted-COO output bit-for-bit on integer-valued
matrices (float32 sums of small integers are exact, so the comparison is
independent of summation order), while never materializing the full
(k_a, n, k_b) product stream and poisoning ``ngroups`` on any capacity it
cannot honor.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AccumulatorOverflow, accumulate_stream,
                        ell_cols_from_dense, ell_rows_from_dense, spgemm_coo,
                        spgemm_coo_batched)
from repro.core.formats import EllCols, EllRows
from repro.plan import make_plan

from conftest import random_sparse


def _int_sparse(rng, m, n, density, lo=-4, hi=5):
    return (((rng.random((m, n)) < density)
             * rng.integers(lo, hi, (m, n))).astype(np.float32))


def _ell_pair(a, b, ka=None, kb=None):
    ka = ka or max(1, int((a != 0).sum(0).max()))
    kb = kb or max(1, int((b != 0).sum(1).max()))
    return (ell_rows_from_dense(jnp.array(a), ka),
            ell_cols_from_dense(jnp.array(b), kb))


def _assert_bit_identical(got, ref):
    assert got.cap == ref.cap
    np.testing.assert_array_equal(np.asarray(got.row), np.asarray(ref.row))
    np.testing.assert_array_equal(np.asarray(got.col), np.asarray(ref.col))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(ref.val))
    assert int(got.ngroups) == int(ref.ngroups)


def test_stream_bit_identical_to_sort():
    """The matrix zoo: square, rectangular, skewed, duplicate-heavy,
    padding-heavy (oversized k) and empty — all bit-identical to 'sort'."""
    rng = np.random.default_rng(0)
    cases = []
    cases.append(_ell_pair(_int_sparse(rng, 32, 32, 0.25),
                           _int_sparse(rng, 32, 32, 0.25)))
    cases.append(_ell_pair(_int_sparse(rng, 24, 40, 0.3),
                           _int_sparse(rng, 40, 56, 0.2)))     # rectangular
    skew_a = _int_sparse(rng, 48, 48, 0.05)
    hot = rng.choice(48, 6, replace=False)
    skew_a[hot] = _int_sparse(rng, 6, 48, 0.7)                 # hot rows
    cases.append(_ell_pair(skew_a, _int_sparse(rng, 48, 48, 0.1)))
    cases.append(_ell_pair(_int_sparse(rng, 16, 16, 0.8),
                           _int_sparse(rng, 16, 16, 0.8)))     # dup-heavy
    cases.append(_ell_pair(_int_sparse(rng, 32, 32, 0.05),
                           _int_sparse(rng, 32, 32, 0.05),
                           ka=12, kb=12))                      # padding-heavy
    z = np.zeros((16, 16), np.float32)
    cases.append(_ell_pair(z, z, ka=2, kb=2))                  # empty
    for ea, eb in cases:
        plan = make_plan(ea, eb, backend="stream")
        ref = spgemm_coo(ea, eb, out_cap=plan.out_cap)
        got = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="stream",
                         plan=plan, check=True)
        _assert_bit_identical(got, ref)
        np.testing.assert_allclose(
            np.asarray(got.to_dense()),
            np.asarray(ea.to_dense()) @ np.asarray(eb.to_dense()), atol=1e-4)


def test_stream_group_invariance():
    """Slab grouping is a performance knob: any group size yields the
    identical sorted COO (coordinates exactly; integer values exactly)."""
    rng = np.random.default_rng(1)
    ea, eb = _ell_pair(_int_sparse(rng, 32, 32, 0.3),
                       _int_sparse(rng, 32, 32, 0.3))
    plan = make_plan(ea, eb, backend="stream")
    ref = None
    for group in (1, 2, 3, ea.k):
        # stream_cap is sized per group tile — let it default to the full
        # tile when overriding the group (the planner scales them together)
        p = dataclasses.replace(plan, stream_group=group, stream_cap=None)
        got = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="stream",
                         plan=p, check=True)
        if ref is None:
            ref = got
        else:
            _assert_bit_identical(got, ref)


def test_stream_flat_and_slab_paths_match():
    """accumulate_stream(backend='stream') on the materialized 3-D stream is
    float-exact against the never-materialized spgemm_coo stream path (same
    tiles in the same order), and the 1-D chunked path matches 'sort' on
    integer matrices."""
    from repro.core.sccp import sccp_multiply
    rng = np.random.default_rng(2)
    a = (rng.random((32, 32)) * (rng.random((32, 32)) < 0.3)).astype(np.float32)
    b = (rng.random((32, 32)) * (rng.random((32, 32)) < 0.3)).astype(np.float32)
    ea, eb = _ell_pair(a, b)
    plan = make_plan(ea, eb, backend="stream")
    val, row, col = sccp_multiply(ea, eb)
    got = accumulate_stream(row, col, val, plan.out_cap, 32, 32,
                            backend="stream", plan=plan)
    ref = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="stream",
                     plan=plan)
    np.testing.assert_array_equal(np.asarray(got.row), np.asarray(ref.row))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(ref.val))
    # 1-D chunked flat path vs the sort oracle (integers → exact)
    ai = np.sign(a).astype(np.float32)
    bi = np.sign(b).astype(np.float32)
    eai, ebi = _ell_pair(ai, bi)
    vi, ri, ci = sccp_multiply(eai, ebi)
    flat = accumulate_stream(ri.reshape(-1), ci.reshape(-1), vi.reshape(-1),
                             1024, 32, 32, backend="stream", tile=512)
    srt = spgemm_coo(eai, ebi, out_cap=1024)
    _assert_bit_identical(flat, srt)


def test_stream_undersized_stream_cap_poisons():
    """A stream_cap below the per-tile unique count must poison ngroups and
    trip check_no_overflow — never silently drop products."""
    rng = np.random.default_rng(3)
    ea, eb = _ell_pair(_int_sparse(rng, 32, 32, 0.5),
                       _int_sparse(rng, 32, 32, 0.5))
    plan = make_plan(ea, eb, backend="stream")
    tiny = dataclasses.replace(plan, stream_cap=2)
    coo = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="stream",
                     plan=tiny)
    assert bool(coo.overflowed()), int(coo.ngroups)
    with pytest.raises(AccumulatorOverflow):
        spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="stream",
                   plan=tiny, check=True)
    # planner-sized caps never drop
    clean = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="stream",
                       plan=plan, check=True)
    assert not bool(clean.overflowed())


def test_stream_undersized_out_cap_overflow():
    rng = np.random.default_rng(4)
    ea, eb = _ell_pair(_int_sparse(rng, 16, 16, 0.5),
                       _int_sparse(rng, 16, 16, 0.5))
    with pytest.raises(AccumulatorOverflow):
        spgemm_coo(ea, eb, out_cap=4, accumulator="stream", check=True)


def test_stream_batched_matches_per_slice():
    rng = np.random.default_rng(5)
    n, bsz = 24, 3
    As = np.stack([_int_sparse(rng, n, n, 0.2) for _ in range(bsz)])
    Bs = np.stack([_int_sparse(rng, n, n, 0.2) for _ in range(bsz)])
    als = [ell_rows_from_dense(jnp.array(As[i]), 10) for i in range(bsz)]
    bls = [ell_cols_from_dense(jnp.array(Bs[i]), 10) for i in range(bsz)]
    ab = EllRows(val=jnp.stack([x.val for x in als]),
                 idx=jnp.stack([x.idx for x in als]), n_rows=n)
    bb = EllCols(val=jnp.stack([x.val for x in bls]),
                 idx=jnp.stack([x.idx for x in bls]), n_cols=n)
    plan = make_plan(als[0], bls[0], backend="stream", slack=2.0)
    coo = spgemm_coo_batched(ab, bb, plan.out_cap, accumulator="stream",
                             plan=plan, check=True)
    assert coo.ngroups.shape == (bsz,)
    # deliberate representative-slice reuse across patterns: opt out of the
    # stale-plan fingerprint check (slack=2.0 sized the caps for it)
    shared = dataclasses.replace(plan, fp=None)
    for i in range(bsz):
        ref = spgemm_coo(als[i], bls[i], out_cap=plan.out_cap,
                         accumulator="stream", plan=shared)
        np.testing.assert_array_equal(np.asarray(coo.row[i]),
                                      np.asarray(ref.row))
        np.testing.assert_array_equal(np.asarray(coo.val[i]),
                                      np.asarray(ref.val))
        assert int(coo.ngroups[i]) == int(ref.ngroups)


def test_stream_jit_compatible():
    from functools import partial
    rng = np.random.default_rng(6)
    a = _int_sparse(rng, 24, 24, 0.3)
    b = _int_sparse(rng, 24, 24, 0.3)
    ea, eb = _ell_pair(a, b)
    plan = make_plan(ea, eb, backend="stream")
    f = jax.jit(partial(spgemm_coo, out_cap=plan.out_cap,
                        accumulator="stream", plan=plan))
    np.testing.assert_allclose(np.asarray(f(ea, eb).to_dense()), a @ b,
                               atol=1e-4)


def test_planner_stream_sizing_and_budget():
    """stream_cap/stream_group come from the exact per-slab histogram and
    the memory model; a tight mem_budget forces the streaming backend."""
    from repro.plan import symbolic
    rng = np.random.default_rng(7)
    ea, eb = _ell_pair(_int_sparse(rng, 48, 48, 0.2),
                       _int_sparse(rng, 48, 48, 0.2))
    plan = make_plan(ea, eb)
    assert plan.stream_cap & (plan.stream_cap - 1) == 0
    assert plan.stream_group >= 1
    max_slab = int(symbolic.max_slab_products(ea, eb))
    # never-drop: the compaction width covers any group tile's products
    # (a tile's uniques never exceed its products)
    assert plan.stream_cap >= plan.stream_group * max_slab
    assert {"cost_stream", "interm_stream", "interm_sort"} <= set(plan.est)
    # the streamed intermediate honors the planner's sizing margin
    assert plan.est["interm_stream"] * 4 <= plan.est["interm_sort"] \
        or plan.stream_group == 1
    # memory-aware override: an impossible budget forces 'stream'
    assert make_plan(ea, eb, mem_budget=1).backend == "stream"
    coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="auto",
                     plan=make_plan(ea, eb, mem_budget=1), check=True)
    np.testing.assert_allclose(
        np.asarray(coo.to_dense()),
        np.asarray(ea.to_dense()) @ np.asarray(eb.to_dense()), atol=1e-4)


def test_stream_property_vs_dense_oracle(rng):
    for seed in range(4):
        r = np.random.default_rng(seed)
        n = int(r.integers(8, 40))
        dens = float(r.uniform(0.05, 0.5))
        a = random_sparse(r, n, n, dens)
        b = random_sparse(r, n, n, dens)
        ea, eb = _ell_pair(a, b)
        coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="stream",
                         check=True)
        np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b,
                                   atol=1e-3)
