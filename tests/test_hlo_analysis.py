"""Unit tests for the trip-count-aware HLO analyzer."""
import textwrap

from repro.launch.hlo_analysis import HloModule, analyze_hlo

SAMPLE = textwrap.dedent("""
HloModule test

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %c), direction=LT
}

%body (p2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p2 = (s32[], f32[8,8]) parameter(0)
  %iv2 = s32[] get-tuple-element(%p2), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p2), index=1
  %dot.1 = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups={}
  %one = s32[] constant(1)
  %niv = s32[] add(%iv2, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%niv, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%zero, %a)
  %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
""")


def test_trip_count_multiplication():
    out = analyze_hlo(SAMPLE)
    # dot: 2*8*8*8 = 1024 flops, × 5 trips
    assert out["flops"] == 5 * 1024
    # all-reduce output 8*8*4 bytes × 5 trips
    assert out["collective_bytes"]["all-reduce"] == 5 * 256
    assert out["collective_count"] == 5


def test_shape_parsing():
    mod = HloModule(SAMPLE)
    assert mod.trip_count("cond") == 5
    assert "dot.1" in mod.shape_of


def test_real_dryrun_consistency():
    """On a real cell, trip-count FLOPs must exceed raw HloCostAnalysis and
    land within 3x of the analytic 6·N·D (+ recompute / attention)."""
    import json
    from pathlib import Path
    p = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    cells = sorted(p.glob("mistral-large-123b__train_4k__pod16x16.json"))
    if not cells:
        import pytest
        pytest.skip("no dry-run artifacts present")
    d = json.loads(cells[0].read_text())
    model_flops = 6 * d["n_params"] * d["global_batch"] * d["seq_len"] / d["n_devices"]
    assert d["hlo_flops_tc"] > d["hlo_flops"] * 10   # while-loop correction
    assert model_flops < d["hlo_flops_tc"] < 3 * model_flops
