import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 300):
    """Run a python snippet in a subprocess with N fake host devices
    (jax locks the device count at first init, so multi-device tests need
    their own process)."""
    env = {"XLA_FLAGS": f"--xla_force_host_platform_device_count={n_devices}",
           "PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise AssertionError(f"subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_sparse(rng, n, m, density, dtype=np.float32):
    a = (rng.random((n, m)) < density) * rng.standard_normal((n, m))
    return a.astype(dtype)
