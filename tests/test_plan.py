"""Adaptive planner: symbolic nnz(C) sizing + backend selection + routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # offline: fixed-seed shim
    from _propcheck import given, settings, strategies as st

from repro.core import (AccumulatorOverflow, ell_cols_from_dense,
                        ell_rows_from_dense, spgemm_coo)
from repro.core.hwmodel import stats_from_ell, stats_from_scipy
from repro.plan import BACKENDS, Plan, make_plan, symbolic

from conftest import random_sparse


def _pair(rng, n=32, density=0.2, m=None, skew=0.0):
    m = m or n
    a = random_sparse(rng, n, n, density)
    b = random_sparse(rng, n, m, density)
    if skew:                                  # densify a few rows/cols hard
        hot = rng.integers(0, n, max(1, n // 8))
        a[hot] = rng.standard_normal((len(hot), n)).astype(np.float32) * (
            rng.random((len(hot), n)) < skew)
        b[:, hot % m] = (rng.standard_normal((n, len(hot))).astype(np.float32)
                         * (rng.random((n, len(hot))) < skew))
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    return (a, b,
            ell_rows_from_dense(jnp.array(a), ka),
            ell_cols_from_dense(jnp.array(b), kb))


def test_symbolic_exact_nnz_matches_oracle(rng):
    a, b, ea, eb = _pair(rng)
    true_nnz = int((np.abs(a @ b) > 0).sum())
    assert int(symbolic.exact_nnz(ea, eb)) == true_nnz
    assert int(symbolic.upper_bound_nnz(ea, eb)) >= true_nnz
    per_row = np.asarray(symbolic.exact_nnz_rows(ea, eb))
    np.testing.assert_array_equal(per_row, (np.abs(a @ b) > 0).sum(axis=1))


def test_symbolic_bounds_ordering(rng):
    """exact ≤ row-flop upper bound ≤ total products, on varied shapes."""
    for n, dens in [(16, 0.1), (48, 0.3), (64, 0.05)]:
        a, b, ea, eb = _pair(rng, n=n, density=dens)
        exact = int(symbolic.exact_nnz(ea, eb))
        ub = int(symbolic.upper_bound_nnz(ea, eb))
        prods = int(symbolic.product_count(ea, eb))
        assert exact <= ub <= prods


def test_out_cap_auto_contract(rng):
    """auto cap ≥ exact nnz, lane-aligned, honors slack."""
    a, b, ea, eb = _pair(rng)
    exact = int(symbolic.exact_nnz(ea, eb))
    cap = symbolic.out_cap_auto(ea, eb)
    assert cap >= exact and cap % symbolic.LANE == 0
    assert symbolic.out_cap_auto(ea, eb, slack=2.0) >= 2 * exact
    loose = symbolic.out_cap_auto(ea, eb, exact=False)
    assert loose >= cap - symbolic.LANE      # bound dominates exact


def test_stats_from_ell_matches_scipy(rng):
    import scipy.sparse as sp
    a, b, ea, eb = _pair(rng)
    s_sp = stats_from_scipy(sp.csr_matrix(a), sp.csr_matrix(b))
    s_el = stats_from_ell(ea, eb, nnz_c=int(symbolic.exact_nnz(ea, eb)))
    assert s_el.nnz_a == s_sp.nnz_a and s_el.nnz_b == s_sp.nnz_b
    assert s_el.valid_products == s_sp.valid_products
    assert s_el.nnz_c == s_sp.nnz_c
    np.testing.assert_allclose(s_el.sigma, s_sp.sigma, atol=1e-5)


def test_make_plan_static_and_sized(rng):
    a, b, ea, eb = _pair(rng)
    plan = make_plan(ea, eb)
    assert plan.backend in BACKENDS
    assert plan.out_cap >= int(symbolic.exact_nnz(ea, eb))
    for f in ("out_cap", "tile", "n_buckets", "bucket_cap", "n_blocks",
              "block_cap"):
        assert isinstance(getattr(plan, f), int), f
    assert plan.bucket_cap & (plan.bucket_cap - 1) == 0
    assert plan.block_cap & (plan.block_cap - 1) == 0
    assert set(f"cost_{k}" for k in BACKENDS) <= set(plan.est)


@pytest.mark.parametrize("accumulator",
                         ["sort", "tiled", "bucket", "hash", "stream",
                          "search"])
def test_all_backends_match_dense_oracle(rng, accumulator):
    """The matrix zoo: square/rectangular, sparse/dense-ish, skewed."""
    for n, m, dens, skew in [(32, 32, 0.2, 0.0), (24, 40, 0.3, 0.0),
                             (48, 48, 0.1, 0.6), (16, 16, 0.5, 0.0)]:
        a, b, ea, eb = _pair(np.random.default_rng(n + m), n=n, m=m,
                             density=dens, skew=skew)
        coo = spgemm_coo(ea, eb, out_cap="auto", accumulator=accumulator,
                         check=True)
        np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b,
                                   atol=1e-4)
        r, c = np.asarray(coo.row), np.asarray(coo.col)
        mvalid = r >= 0
        keys = r[mvalid].astype(np.int64) * m + c[mvalid]
        assert (np.diff(keys) > 0).all(), "sorted, duplicate-free"


def test_backends_identical_coordinates(rng):
    """All six backends agree bit-for-bit on the output coordinates."""
    a, b, ea, eb = _pair(rng, n=40, density=0.25)
    cap = symbolic.out_cap_auto(ea, eb)
    ref = spgemm_coo(ea, eb, out_cap=cap, accumulator="sort")
    for acc in ("tiled", "bucket", "hash", "stream", "search"):
        got = spgemm_coo(ea, eb, out_cap=cap, accumulator=acc)
        np.testing.assert_array_equal(np.asarray(ref.row), np.asarray(got.row))
        np.testing.assert_array_equal(np.asarray(ref.col), np.asarray(got.col))
        np.testing.assert_allclose(np.asarray(ref.val), np.asarray(got.val),
                                   atol=1e-5)
        assert int(ref.ngroups) == int(got.ngroups)


def test_auto_auto_end_to_end(rng):
    a, b, ea, eb = _pair(rng)
    coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="auto", check=True)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-4)
    assert coo.cap >= int(coo.ngroups)
    # bare call: symbolic cap sizing but conservative 'sort' backend
    bare = spgemm_coo(ea, eb, check=True)
    np.testing.assert_allclose(np.asarray(bare.to_dense()), a @ b, atol=1e-4)


def test_planned_backends_never_drop(rng):
    """Planner-sized bucket/table caps guarantee dropped == 0."""
    for be in ("bucket", "hash"):
        a, b, ea, eb = _pair(rng, n=48, density=0.3, skew=0.7)
        plan = make_plan(ea, eb, backend=be)
        coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="auto",
                         plan=plan, check=True)     # check raises on drops
        np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b,
                                   atol=1e-4)


def test_backend_drops_poison_ngroups(rng):
    """Undersized bucket/table must flag overflow, and check=True raises."""
    a, b, ea, eb = _pair(rng, n=32, density=0.4)
    for be, plan in [
        ("bucket", Plan(backend="bucket", out_cap=32 * 32, n_buckets=2,
                        bucket_cap=128)),
        ("hash", Plan(backend="hash", out_cap=32 * 32, n_blocks=2,
                      block_cap=128)),
    ]:
        coo = spgemm_coo(ea, eb, out_cap=32 * 32, accumulator=be, plan=plan)
        assert bool(coo.overflowed()), be
        with pytest.raises(AccumulatorOverflow):
            spgemm_coo(ea, eb, out_cap=32 * 32, accumulator=be, plan=plan,
                       check=True)


def test_plan_is_jit_and_vmap_compatible(rng):
    from functools import partial
    from repro.core import spgemm_coo_batched
    a, b, ea, eb = _pair(rng)
    plan = make_plan(ea, eb, backend="bucket")
    f = jax.jit(partial(spgemm_coo, out_cap=plan.out_cap,
                        accumulator="bucket", plan=plan))
    np.testing.assert_allclose(np.asarray(f(ea, eb).to_dense()), a @ b,
                               atol=1e-4)
    batched = jax.tree.map(lambda l: jnp.stack([l, l]), (ea, eb))
    coo = spgemm_coo_batched(batched[0], batched[1], plan.out_cap,
                             accumulator="hash", check=True)
    assert coo.ngroups.shape == (2,)
    with pytest.raises(ValueError):
        spgemm_coo_batched(batched[0], batched[1], "auto")
    # a jit-traced bare call must fail with the contract error, not a
    # ConcretizationTypeError from deep inside the planner
    with pytest.raises(ValueError, match="concrete"):
        jax.jit(spgemm_coo)(ea, eb)


def test_plan_empty_operands(rng):
    """Degenerate planning input: all-zero operands must plan and run."""
    z = jnp.zeros((16, 16), jnp.float32)
    ea = ell_rows_from_dense(z, 1)
    eb = ell_cols_from_dense(z, 1)
    assert int(symbolic.exact_nnz(ea, eb)) == 0
    plan = make_plan(ea, eb)
    assert plan.out_cap >= symbolic.LANE
    for acc in ("sort", "tiled", "bucket", "hash", "stream", "search"):
        coo = spgemm_coo(ea, eb, out_cap="auto", accumulator=acc, check=True)
        assert int(coo.ngroups) == 0
        assert not np.asarray(coo.to_dense()).any()


def test_oversized_coordinate_space_routes_to_sort(rng):
    """n_rows*n_cols ≥ 2³¹ can't use packed int32 keys: spgemm_coo must
    route every backend to the unpacked two-key sort path with correct
    coordinates, the kernels must refuse, and the planner must not pick a
    packed-key backend."""
    from repro.kernels import ops
    n_rows = n_cols = 1 << 16               # 2^32 coordinate space
    k, n = 2, 4
    r = np.asarray([[0, 40000, 65535, 7], [1, 2, 3, -1]], np.int32)
    c = np.asarray([[5, 60000, 65535, 9], [6, 7, 8, -1]], np.int32)
    from repro.core.formats import EllCols, EllRows
    ea = EllRows(val=jnp.ones((k, n), jnp.float32) * (r >= 0),
                 idx=jnp.asarray(r), n_rows=n_rows)
    eb = EllCols(val=jnp.ones((n, k), jnp.float32) * (c.T >= 0),
                 idx=jnp.asarray(c.T), n_cols=n_cols)
    expect = {}
    for i in range(k):
        for j in range(n):
            for l in range(k):
                if r[i, j] >= 0 and c[l, j] >= 0:
                    expect[(int(r[i, j]), int(c[l, j]))] = \
                        expect.get((int(r[i, j]), int(c[l, j])), 0) + 1.0
    for acc in ("sort", "tiled", "bucket", "hash", "stream", "search"):
        coo = spgemm_coo(ea, eb, out_cap=64, accumulator=acc, check=True)
        rr, cc, vv = map(np.asarray, (coo.row, coo.col, coo.val))
        got = {(int(a_), int(b_)): float(v_)
               for a_, b_, v_ in zip(rr, cc, vv) if a_ >= 0}
        assert got == expect, acc
    with pytest.raises(ValueError):
        ops.sort_merge(jnp.zeros(4, jnp.int32), jnp.zeros(4, jnp.int32),
                       jnp.zeros(4, jnp.float32), n_rows, n_cols)
    with pytest.raises(ValueError):
        make_plan(ea, eb, backend="hash")
    assert make_plan(ea, eb).backend == "sort"
    # auto-sizing with a pinned packed-key backend must route, not reject
    coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="tiled", check=True)
    got = {(int(a_), int(b_)): float(v_) for a_, b_, v_ in
           zip(*map(np.asarray, (coo.row, coo.col, coo.val))) if a_ >= 0}
    assert got == expect


def test_check_flag_on_sort_backend(rng):
    """Satellite: spgemm_coo(check=True) == accumulate_checked composition."""
    a, b, ea, eb = _pair(rng, n=16, density=0.4)
    with pytest.raises(AccumulatorOverflow):
        spgemm_coo(ea, eb, out_cap=4, check=True)
    ok = spgemm_coo(ea, eb, out_cap=16 * 16, check=True)
    np.testing.assert_allclose(np.asarray(ok.to_dense()), a @ b, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(8, 40), density=st.floats(0.05, 0.45),
       backend=st.sampled_from(["bucket", "hash"]),
       seed=st.integers(0, 2 ** 16))
def test_planned_backend_property(n, density, backend, seed):
    rng = np.random.default_rng(seed)
    a = random_sparse(rng, n, n, density)
    b = random_sparse(rng, n, n, density)
    ka = max(1, int((a != 0).sum(0).max()))
    kb = max(1, int((b != 0).sum(1).max()))
    ea = ell_rows_from_dense(jnp.array(a), ka)
    eb = ell_cols_from_dense(jnp.array(b), kb)
    plan = make_plan(ea, eb, backend=backend)
    coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="auto", plan=plan,
                     check=True)
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-3)


# ---------------------------------------------------------------------------
# Distributed planning (DistPlan / per-shard symbolic bounds)
# ---------------------------------------------------------------------------

def test_per_shard_products_partition(rng):
    """Per-shard product counts partition the exact global product count,
    for divisible and non-divisible slab counts alike."""
    from repro.core.sccp import count_products
    a, b, ea, eb = _pair(rng, n=32, density=0.3)
    total = int(count_products(ea, eb))
    for n_shards in (1, 2, 3, 8):
        per = np.asarray(symbolic.per_shard_products(ea, eb, n_shards))
        assert per.shape == (n_shards,)
        assert int(per.sum()) == total

def test_per_block_nnz_partitions_exact_nnz(rng):
    a, b, ea, eb = _pair(rng, n=40, density=0.2)
    exact = int(symbolic.exact_nnz(ea, eb))
    for n_blocks in (1, 4, 7, 8):
        per = np.asarray(symbolic.per_block_nnz(ea, eb, n_blocks))
        assert int(per.sum()) == exact
        bound = np.asarray(symbolic.per_block_nnz(ea, eb, n_blocks,
                                                  exact=False))
        assert (bound >= per).all()

def test_make_dist_plan_static_and_safe(rng):
    from repro.plan import SCHEDULES, make_dist_plan
    a, b, ea, eb = _pair(rng, n=48, density=0.15, skew=0.6)
    dp = make_dist_plan(ea, eb, n_dev=8)
    assert dp.schedule in SCHEDULES and dp.n_dev == 8
    for f in ("rows_per_dev", "local_cap", "bin_cap", "block_cap", "out_cap"):
        assert isinstance(getattr(dp, f), int), f
    # capacities dominate their exact histograms (never-drop guarantee)
    assert dp.block_cap >= int(np.asarray(
        symbolic.per_block_nnz(ea, eb, 8)).max())
    assert dp.local_cap >= 0 and dp.bin_cap <= dp.block_cap + dp.local_cap
    assert dp.out_cap == dp.base.out_cap
    # pinning wins
    assert make_dist_plan(ea, eb, n_dev=4, schedule="cstat").schedule == "cstat"
    assert make_dist_plan(ea, eb, n_dev=4, backend="hash").base.backend == "hash"
    with pytest.raises(ValueError):
        make_dist_plan(ea, eb, n_dev=8, schedule="spiral")
    with pytest.raises(ValueError):
        make_dist_plan(ea, eb, n_dev=0)

def test_dist_plan_schedule_tradeoff():
    """Schedule choice follows the comm model: huge A + few partials →
    'ring' (don't replicate A, and summa's A-panel hops cost more than the
    tiny B rotation); wide B → 'summa' (the 2D grid moves (pc−1)/p of A +
    (pr−1)/p of B instead of all of B, beating both 1D options)."""
    from repro.plan import make_dist_plan
    rng = np.random.default_rng(3)
    # wide A (many slabs) against narrow B: A replication is the dominant
    # cost, and any 2D grid must hop ≥ one grid-row's worth of wide-A panels
    a = random_sparse(rng, 64, 64, 0.9)
    b = random_sparse(rng, 64, 64, 0.02)
    ea = ell_rows_from_dense(jnp.array(a), 60)
    eb = ell_cols_from_dense(jnp.array(b), 4)
    dp = make_dist_plan(ea, eb, n_dev=8)
    assert dp.est["cstat_comm_bytes"] > dp.est["ring_comm_bytes"]
    assert dp.est["summa_comm_bytes"] > dp.est["ring_comm_bytes"]
    assert dp.schedule == "ring"
    # narrow A against wide B: rotating all of B is the 1D bottleneck; the
    # 2D grid picks pr=2, pc=4 (hop the narrow A further, the wide B less)
    # and undercuts both 1D schedules
    a2 = random_sparse(rng, 64, 64, 0.02)
    b2 = random_sparse(rng, 64, 64, 0.9)
    ea2 = ell_rows_from_dense(jnp.array(a2), 4)
    eb2 = ell_cols_from_dense(jnp.array(b2), 60)
    dp2 = make_dist_plan(ea2, eb2, n_dev=8)
    assert dp2.est["ring_comm_bytes"] > dp2.est["cstat_comm_bytes"]
    assert dp2.est["summa_comm_bytes"] < dp2.est["cstat_comm_bytes"]
    assert dp2.schedule == "summa"
    assert (dp2.pr, dp2.pc) == (2, 4)
    # pinning a 1D schedule still wins over the model
    assert make_dist_plan(ea2, eb2, n_dev=8, schedule="cstat").schedule == "cstat"


def test_dist_plan_grid_selection_and_degenerate_fallback():
    """Satellite: 'auto' can never pick a degenerate 2D grid. Meshes with no
    pr,pc ≥ 2 factorization (1, 2, primes) model summa with the 1D ring
    bytes, so the strict-improvement rule keeps them on 1D schedules."""
    from repro.plan import make_dist_plan
    from repro.plan.planner import best_grid, grid_candidates
    assert grid_candidates(8) == [(2, 4), (4, 2)]
    assert grid_candidates(2) == [] and grid_candidates(7) == []
    assert best_grid(2, 16, 16) is None
    assert best_grid(2, 16, 16, allow_degenerate=True) in ((2, 1), (1, 2))
    assert best_grid(16, 4, 60) == (2, 8)     # hop narrow A more, wide B less
    rng = np.random.default_rng(5)
    a = random_sparse(rng, 48, 48, 0.05)
    b = random_sparse(rng, 48, 48, 0.6)
    ea = ell_rows_from_dense(jnp.array(a), 6)
    eb = ell_cols_from_dense(jnp.array(b), 36)
    for n_dev in (1, 2, 3, 7):
        dp = make_dist_plan(ea, eb, n_dev=n_dev)
        assert dp.schedule != "summa", n_dev
        # degenerate grids are modeled with 1D bytes — no phantom savings
        assert dp.est["summa_comm_bytes"] == dp.est["ring_comm_bytes"]
    # the same operands on a factorable mesh do pick the 2D schedule
    assert make_dist_plan(ea, eb, n_dev=8).schedule == "summa"


def test_per_grid_products_invariants(rng):
    """per_grid_products partitions the exact product count; its (p, 1)
    column degenerates to per_shard_products; and local_cap dominates every
    factorization's largest cell (the replace(dp, pr=, pc=) contract)."""
    from repro.plan import make_dist_plan
    from repro.plan.planner import grid_candidates
    a, b, ea, eb = _pair(rng, n=40, density=0.2, skew=0.5)
    total = int(np.asarray(symbolic.product_count(ea, eb)))
    for pr, pc in ((2, 4), (4, 2), (8, 1), (1, 8), (2, 2)):
        g = np.asarray(symbolic.per_grid_products(ea, eb, pr, pc))
        assert g.shape == (pr, pc)
        assert int(g.sum()) == total, (pr, pc)
    np.testing.assert_array_equal(
        np.asarray(symbolic.per_grid_products(ea, eb, 8, 1))[:, 0],
        np.asarray(symbolic.per_shard_products(ea, eb, 8)))
    dp = make_dist_plan(ea, eb, n_dev=8)
    nnz_c = int(dp.est["nnz_c"])
    for gr, gc in grid_candidates(8) + [(1, 8), (8, 1)]:
        cell = int(np.asarray(
            symbolic.per_grid_products(ea, eb, gr, gc)).max())
        assert dp.local_cap >= min(nnz_c, cell), (gr, gc)

def test_accumulate_stream_matches_spgemm_backends(rng):
    """accumulate_stream is the factored backend dispatch: feeding it the
    raw SCCP stream reproduces spgemm_coo for every backend."""
    from repro.core import accumulate_stream
    from repro.core.sccp import sccp_multiply
    a, b, ea, eb = _pair(rng, n=24, density=0.3)
    plan = make_plan(ea, eb)
    val, row, col = sccp_multiply(ea, eb)
    for backend in BACKENDS:
        ref = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator=backend,
                         plan=plan)
        got = accumulate_stream(row, col, val, plan.out_cap, ea.n_rows,
                                eb.n_cols, backend=backend, plan=plan)
        np.testing.assert_array_equal(np.asarray(got.row), np.asarray(ref.row))
        np.testing.assert_array_equal(np.asarray(got.val), np.asarray(ref.val))
    with pytest.raises(ValueError):
        accumulate_stream(row, col, val, 64, ea.n_rows, eb.n_cols,
                          backend="nope")
