"""The ``'search'`` accumulation backend: the paper's own in-situ-search
accumulation (Alg. 1 / Fig. 11) as a first-class ``spgemm_coo`` backend.

The backend must reproduce the ``'sort'`` backend's sorted-COO output
bit-for-bit on integer-valued matrices (float32 sums of small integers are
exact) across the matrix zoo — including batched, truncated and warm
numeric-phase calls — while its three realizations (XLA, compiled Pallas,
faithful iterated Alg. 1) stay mutually bit-identical. Also the home of the
extreme-key boundary regressions: the packed-key sentinels
(``KEY_INVALID``/``KEY_INVALID-1``) must never collide with a legal
coordinate key, whose maximum is 2³¹−3.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AccumulatorOverflow, ell_cols_from_dense,
                        ell_rows_from_dense, spgemm_coo, spgemm_coo_batched)
from repro.core.formats import EllCols, EllRows
from repro.core.spgemm import spgemm_coo_numeric
from repro.plan import make_plan, make_structure

from conftest import random_sparse


def _int_sparse(rng, m, n, density, lo=-4, hi=5):
    return (((rng.random((m, n)) < density)
             * rng.integers(lo, hi, (m, n))).astype(np.float32))


def _ell_pair(a, b, ka=None, kb=None):
    ka = ka or max(1, int((a != 0).sum(0).max()))
    kb = kb or max(1, int((b != 0).sum(1).max()))
    return (ell_rows_from_dense(jnp.array(a), ka),
            ell_cols_from_dense(jnp.array(b), kb))


def _assert_bit_identical(got, ref):
    assert got.cap == ref.cap
    np.testing.assert_array_equal(np.asarray(got.row), np.asarray(ref.row))
    np.testing.assert_array_equal(np.asarray(got.col), np.asarray(ref.col))
    np.testing.assert_array_equal(np.asarray(got.val), np.asarray(ref.val))
    assert int(got.ngroups) == int(ref.ngroups)


def test_search_bit_identical_to_sort():
    """The matrix zoo: square, rectangular, skewed, duplicate-heavy,
    padding-heavy (oversized k) and empty — all bit-identical to 'sort'."""
    rng = np.random.default_rng(0)
    cases = []
    cases.append(_ell_pair(_int_sparse(rng, 32, 32, 0.25),
                           _int_sparse(rng, 32, 32, 0.25)))
    cases.append(_ell_pair(_int_sparse(rng, 24, 40, 0.3),
                           _int_sparse(rng, 40, 56, 0.2)))     # rectangular
    skew_a = _int_sparse(rng, 48, 48, 0.05)
    hot = rng.choice(48, 6, replace=False)
    skew_a[hot] = _int_sparse(rng, 6, 48, 0.7)                 # hot rows
    cases.append(_ell_pair(skew_a, _int_sparse(rng, 48, 48, 0.1)))
    cases.append(_ell_pair(_int_sparse(rng, 16, 16, 0.8),
                           _int_sparse(rng, 16, 16, 0.8)))     # dup-heavy
    cases.append(_ell_pair(_int_sparse(rng, 32, 32, 0.05),
                           _int_sparse(rng, 32, 32, 0.05),
                           ka=12, kb=12))                      # padding-heavy
    z = np.zeros((16, 16), np.float32)
    cases.append(_ell_pair(z, z, ka=2, kb=2))                  # empty
    for ea, eb in cases:
        plan = make_plan(ea, eb, backend="search")
        ref = spgemm_coo(ea, eb, out_cap=plan.out_cap)
        got = spgemm_coo(ea, eb, out_cap=plan.out_cap, accumulator="search",
                         plan=plan, check=True)
        _assert_bit_identical(got, ref)
        np.testing.assert_allclose(
            np.asarray(got.to_dense()),
            np.asarray(ea.to_dense()) @ np.asarray(eb.to_dense()), atol=1e-4)


def test_search_truncation_matches_sort():
    """An undersized out_cap keeps the first out_cap (lowest) unique keys
    and reports the TRUE group count — exactly the 'sort' backend's
    truncation contract, bit-for-bit — and check=True raises for both."""
    rng = np.random.default_rng(1)
    ea, eb = _ell_pair(_int_sparse(rng, 32, 32, 0.4),
                       _int_sparse(rng, 32, 32, 0.4))
    full = spgemm_coo(ea, eb, out_cap="auto")
    cap = int(full.ngroups) // 2
    assert cap > 0
    ref = spgemm_coo(ea, eb, out_cap=cap)
    got = spgemm_coo(ea, eb, out_cap=cap, accumulator="search")
    _assert_bit_identical(got, ref)
    assert bool(got.overflowed())
    with pytest.raises(AccumulatorOverflow):
        spgemm_coo(ea, eb, out_cap=cap, accumulator="search", check=True)


def test_search_batched_matches_per_slice():
    rng = np.random.default_rng(2)
    n, bsz = 24, 3
    As = np.stack([_int_sparse(rng, n, n, 0.2) for _ in range(bsz)])
    Bs = np.stack([_int_sparse(rng, n, n, 0.2) for _ in range(bsz)])
    als = [ell_rows_from_dense(jnp.array(As[i]), 10) for i in range(bsz)]
    bls = [ell_cols_from_dense(jnp.array(Bs[i]), 10) for i in range(bsz)]
    ab = EllRows(val=jnp.stack([x.val for x in als]),
                 idx=jnp.stack([x.idx for x in als]), n_rows=n)
    bb = EllCols(val=jnp.stack([x.val for x in bls]),
                 idx=jnp.stack([x.idx for x in bls]), n_cols=n)
    plan = make_plan(als[0], bls[0], backend="search", slack=2.0)
    coo = spgemm_coo_batched(ab, bb, plan.out_cap, accumulator="search",
                             plan=plan, check=True)
    assert coo.ngroups.shape == (bsz,)
    shared = dataclasses.replace(plan, fp=None)
    for i in range(bsz):
        ref = spgemm_coo(als[i], bls[i], out_cap=plan.out_cap,
                         accumulator="search", plan=shared)
        np.testing.assert_array_equal(np.asarray(coo.row[i]),
                                      np.asarray(ref.row))
        np.testing.assert_array_equal(np.asarray(coo.val[i]),
                                      np.asarray(ref.val))
        assert int(coo.ngroups[i]) == int(ref.ngroups)


def test_search_jit_compatible():
    from functools import partial
    rng = np.random.default_rng(3)
    a = _int_sparse(rng, 24, 24, 0.3)
    b = _int_sparse(rng, 24, 24, 0.3)
    ea, eb = _ell_pair(a, b)
    plan = make_plan(ea, eb, backend="search")
    f = jax.jit(partial(spgemm_coo, out_cap=plan.out_cap,
                        accumulator="search", plan=plan))
    np.testing.assert_allclose(np.asarray(f(ea, eb).to_dense()), a @ b,
                               atol=1e-4)


def test_search_warm_numeric_matches_cold():
    """A search-planned SpgemmStructure feeds the numeric phase: the
    structure's sorted keys ARE the emission result, so warm calls skip
    emission entirely and stay bit-identical to the cold path."""
    rng = np.random.default_rng(4)
    ea, eb = _ell_pair(_int_sparse(rng, 32, 32, 0.3),
                       _int_sparse(rng, 32, 32, 0.3))
    st = make_structure(ea, eb, backend="search")
    assert st.plan.backend == "search"
    ref = spgemm_coo(ea, eb, out_cap=st.out_cap)
    warm = spgemm_coo_numeric(ea, eb, st, check=True)
    _assert_bit_identical(warm, ref)


def test_search_faithful_matches_batched_emission():
    """The literal iterated Alg. 1 scan and the batched key-only network
    emit the identical sorted-unique list; their nnz agrees exactly when
    untruncated and both flag past cap when truncated (the faithful scan's
    count is a floor — it stops scanning at out_cap)."""
    from repro.kernels.insitu_search import KEY_INVALID, emit_sorted_unique
    rng = np.random.default_rng(5)
    key = rng.integers(0, 96, 256).astype(np.int32)
    key[200:] = int(KEY_INVALID)                     # stream padding lanes
    k = jnp.asarray(key)
    n_uniq = len(np.unique(key[:200]))
    uk_b, nnz_b = emit_sorted_unique(k, 128)
    uk_f, nnz_f = emit_sorted_unique(k, 128, faithful=True)
    np.testing.assert_array_equal(np.asarray(uk_b), np.asarray(uk_f))
    assert int(nnz_b) == int(nnz_f) == n_uniq
    cap = n_uniq // 2
    uk_bt, nnz_bt = emit_sorted_unique(k, cap)
    uk_ft, nnz_ft = emit_sorted_unique(k, cap, faithful=True)
    np.testing.assert_array_equal(np.asarray(uk_bt), np.asarray(uk_ft))
    assert int(nnz_bt) == n_uniq                     # batched: true count
    assert int(nnz_ft) > cap                         # faithful: floor past cap


def test_search_interpret_auto_select(monkeypatch):
    """insitu_search mirrors the repo-wide auto-select: the XLA realization
    (minima_mask_xla / jnp.sort / searchsorted, zero pallas_call) off-TPU,
    the compiled Pallas kernels (interpret=False) when the backend is TPU;
    explicit interpret=True reserves the interpreter for kernel tests."""
    import repro.kernels.bitonic_merge as bm
    import repro.kernels.insitu_search as isrch
    seen = []
    real = isrch.pl.pallas_call

    def spy(*args, **kw):
        seen.append(kw.get("interpret"))
        kw["interpret"] = True        # keep it executable on this host
        return real(*args, **kw)

    monkeypatch.setattr(isrch.pl, "pallas_call", spy)

    assert bm.resolve_mode(None) == "xla"       # this host has no TPU
    rng = np.random.default_rng(6)
    k = jnp.asarray(rng.integers(0, 4096, 512), jnp.int32)
    uk_x, nnz_x = isrch.emit_sorted_unique(k, 64)
    slot_x, hit_x = isrch.align_keys(k, uk_x)
    mask_x = isrch.minima_mask_pallas(k)
    isrch.search_emit_sorted(k, max_unique=8)
    assert seen == []                 # auto → pure-XLA path, no Pallas at all

    uk_i, nnz_i = isrch.emit_sorted_unique(k, 64, interpret=True)
    slot_i, hit_i = isrch.align_keys(k, uk_i, interpret=True)
    mask_i = isrch.minima_mask_pallas(k, interpret=True)
    assert seen and all(i is True for i in seen)
    np.testing.assert_array_equal(np.asarray(uk_x), np.asarray(uk_i))
    assert int(nnz_x) == int(nnz_i)
    np.testing.assert_array_equal(np.asarray(slot_x), np.asarray(slot_i))
    np.testing.assert_array_equal(np.asarray(hit_x), np.asarray(hit_i))
    np.testing.assert_array_equal(np.asarray(mask_x), np.asarray(mask_i))

    seen.clear()
    monkeypatch.setattr(isrch.jax, "default_backend", lambda: "tpu")
    assert bm.resolve_mode(None) == "pallas"
    k2 = jnp.asarray(rng.integers(0, 4096, 1024), jnp.int32)  # fresh traces
    uk2, _ = isrch.emit_sorted_unique(k2, 128)
    isrch.align_keys(k2, uk2)
    isrch.minima_mask_pallas(k2)
    assert seen and all(i is False for i in seen)   # compiled on TPU


def test_extreme_key_boundary_all_backends():
    """Largest packable coordinate space: n_rows·n_cols = 2³¹−2 (one below
    the packed-key cutoff), so the maximal legal key is 2³¹−3 =
    KEY_INVALID−2. Neither the KEY_INVALID padding nor the KEY_INVALID−1
    run-tail sentinel (_coo_from_merged's nxt fill) can collide with a real
    key — every packed backend must stay exact with keys at both ends of
    int32, including duplicates on the maximal key."""
    n_rows, n_cols = 2, (1 << 30) - 1
    assert n_rows * n_cols == jnp.iinfo(jnp.int32).max - 1
    k, n = 2, 2
    r = np.asarray([[0, 1], [1, 0]], np.int32)
    c = np.asarray([[0, n_cols - 1], [n_cols - 1, 0]], np.int32)
    ea = EllRows(val=jnp.ones((k, n), jnp.float32), idx=jnp.asarray(r),
                 n_rows=n_rows)
    eb = EllCols(val=jnp.ones((n, k), jnp.float32), idx=jnp.asarray(c.T),
                 n_cols=n_cols)
    expect = {}
    for i in range(k):
        for j in range(n):
            for l in range(k):
                rc = (int(r[i, j]), int(c[l, j]))
                expect[rc] = expect.get(rc, 0) + 1.0
    # keys span the full legal range: 0 … 2³¹−3 == KEY_INVALID−2
    keys = sorted(rr * n_cols + cc for rr, cc in expect)
    assert keys[0] == 0
    assert keys[-1] == int(jnp.iinfo(jnp.int32).max) - 2
    for acc in ("sort", "tiled", "bucket", "hash", "stream", "search"):
        coo = spgemm_coo(ea, eb, out_cap=16, accumulator=acc, check=True)
        rr, cc, vv = map(np.asarray, (coo.row, coo.col, coo.val))
        got = {(int(a_), int(b_)): float(v_)
               for a_, b_, v_ in zip(rr, cc, vv) if a_ >= 0}
        assert got == expect, acc
    # the warm numeric path packs/searches the same extreme keys
    st = make_structure(ea, eb)
    warm = spgemm_coo_numeric(ea, eb, st, check=True)
    ref = spgemm_coo(ea, eb, out_cap=st.out_cap, check=True)
    _assert_bit_identical(warm, ref)


def test_stale_structure_miss_poisons_every_backend_plan():
    """Satellite: a structure reused (validate=False) on operands whose
    pattern grew must route the unknown products to the discarded overflow
    slot AND poison ngroups — for structures planned under every backend,
    including the scan-based stream numeric path — so check=True raises
    instead of returning silently-wrong values."""
    rng = np.random.default_rng(7)
    a1, b1 = _ell_pair(_int_sparse(rng, 32, 32, 0.05),
                       _int_sparse(rng, 32, 32, 0.05))
    a2, b2 = _ell_pair(_int_sparse(rng, 32, 32, 0.4),
                       _int_sparse(rng, 32, 32, 0.4))
    for backend in ("sort", "tiled", "bucket", "hash", "stream", "search"):
        st = make_structure(a1, b1, backend=backend)
        clean = spgemm_coo_numeric(a1, b1, st, check=True)
        assert not bool(clean.overflowed()), backend
        stale = spgemm_coo_numeric(a2, b2, st, validate=False)
        assert int(stale.ngroups) > st.out_cap, backend   # poisoned past cap
        with pytest.raises(AccumulatorOverflow):
            spgemm_coo_numeric(a2, b2, st, validate=False, check=True)


def test_planner_search_cost_and_sizing():
    """Duplicate-heavy streams are where alignment beats a full re-sort:
    the model must rank 'search' below 'sort' there, expose its cost and
    intermediate estimates, and the plan's out_cap never drops a group."""
    rng = np.random.default_rng(8)
    ea, eb = _ell_pair(_int_sparse(rng, 48, 48, 0.5),
                       _int_sparse(rng, 48, 48, 0.5))
    plan = make_plan(ea, eb)
    assert {"cost_search", "interm_search"} <= set(plan.est)
    assert plan.est["cost_search"] < plan.est["cost_sort"]
    full = spgemm_coo(ea, eb, out_cap="auto")
    assert plan.out_cap >= int(full.ngroups)          # never-drop sizing
    forced = make_plan(ea, eb, backend="search")
    assert forced.backend == "search"
    coo = spgemm_coo(ea, eb, accumulator="auto", plan=plan, check=True)
    np.testing.assert_allclose(
        np.asarray(coo.to_dense()),
        np.asarray(ea.to_dense()) @ np.asarray(eb.to_dense()), atol=1e-4)


def test_search_property_vs_dense_oracle(rng):
    for seed in range(4):
        r = np.random.default_rng(seed)
        n = int(r.integers(8, 40))
        dens = float(r.uniform(0.05, 0.5))
        a = random_sparse(r, n, n, dens)
        b = random_sparse(r, n, n, dens)
        ea, eb = _ell_pair(a, b)
        coo = spgemm_coo(ea, eb, out_cap="auto", accumulator="search",
                         check=True)
        np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b,
                                   atol=1e-3)
