"""End-to-end LM training driver with checkpoint/restart.

Default: a ~10M-param qwen2-family model, 120 steps on CPU (~ minutes).
``--full`` trains a ~100M-param model for 300 steps (the deliverable-scale
run; budget ~1h on one CPU core, trivial on any accelerator).

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime import Trainer, TrainerConfig


def small_cfg(full: bool) -> ModelConfig:
    if full:   # ~100M params
        return ModelConfig(
            name="lm-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048, vocab=32000,
            qkv_bias=True, tie_embeddings=True, param_dtype="float32",
            compute_dtype="float32", remat="none")
    return ModelConfig(
        name="lm-10m", family="dense", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=4, d_head=32, d_ff=768, vocab=8192,
        qkv_bias=True, tie_embeddings=True, param_dtype="float32",
        compute_dtype="float32", remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    cfg = small_cfg(args.full)
    model = build_model(cfg)
    steps = args.steps or (300 if args.full else 120)
    print(f"[example] {cfg.name}: {model.n_params():,} params, {steps} steps")
    tcfg = TrainerConfig(steps=steps, log_every=10, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir, global_batch=8,
                         seq_len=256 if args.full else 128)
    out = Trainer(model, tcfg, AdamWConfig(lr=1e-3, warmup_steps=20)).run()
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"[example] loss {first:.3f} -> {last:.3f} "
          f"({'improved ✓' if last < first else 'NO IMPROVEMENT ✗'})")


if __name__ == "__main__":
    main()
