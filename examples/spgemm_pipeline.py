"""End-to-end driver of the paper's kind: the A·Aᵀ SpGEMM suite.

Runs the full SPLIM pipeline (hybrid split → SCCP multiply → in-situ-search
merge) over scaled-down versions of the 16 Table-I matrices, validates every
result against scipy, and reports modeled PUM latency/energy + measured
wall time. The ``plan`` column shows what the adaptive planner (repro.plan)
would run for the sorted-COO output: its chosen accumulation backend and
the symbolically derived ``out_cap`` — the planned path is validated
against the oracle as well.

    PYTHONPATH=src python examples/spgemm_pipeline.py [--scale 64]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp
import scipy.sparse as sp

from benchmarks.common import TABLE1
from repro import (ell_cols_from_dense, ell_rows_from_dense, hwmodel, hybrid,
                   make_plan, spgemm)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=256,
                    help="downscale factor for executable validation")
    args = ap.parse_args()

    print(f"{'matrix':>18s} {'dim':>6s} {'nnz':>8s} {'k':>4s} "
          f"{'wall_ms':>8s} {'model_us':>9s} {'model_uJ':>9s} "
          f"{'plan':>14s}  ok")
    for mid, name, dim, nnz, nnz_av, sigma in TABLE1:
        n = max(64, dim // args.scale)
        density = min(0.5, nnz / dim / dim * args.scale)
        rng = np.random.default_rng(mid)
        a = ((rng.random((n, n)) < density)
             * rng.standard_normal((n, n))).astype(np.float32)
        at = a.T.copy()
        k = hybrid.ell_width_rule((a != 0).sum(0))
        ha = hybrid.split_rows_hybrid(jnp.array(a), k, coo_cap=4 * n)
        hb = hybrid.split_cols_hybrid(jnp.array(at), k, coo_cap=4 * n)
        f = jax.jit(hybrid.hybrid_spgemm_dense)
        c = np.asarray(f(ha, hb))           # compile
        t0 = time.perf_counter()
        c = np.asarray(f(ha, hb))
        wall = (time.perf_counter() - t0) * 1e3
        ref = a @ at
        ok = np.allclose(c, ref, atol=1e-2)
        counts = (a != 0).sum(0)
        s = hwmodel.MatrixStats(
            n=n, nnz_a=int(counts.sum()), nnz_b=int(counts.sum()),
            k_a=k, k_b=k,
            valid_products=int((counts.astype(np.int64) ** 2).sum()),
            nnz_c=int((np.abs(ref) > 1e-7).sum()),
            sigma=float(counts.std()))
        lat = hwmodel.splim_latency(s)["total"] * 1e6
        en = hwmodel.splim_energy(s)["total"] * 1e6
        # Adaptive planner on the lossless ELL pair: symbolic out_cap +
        # backend choice, validated on the planned sorted-COO path.
        ka = max(1, int((a != 0).sum(0).max()))
        kb = max(1, int((at != 0).sum(1).max()))
        ea = ell_rows_from_dense(jnp.array(a), ka)
        eb = ell_cols_from_dense(jnp.array(at), kb)
        plan = make_plan(ea, eb)
        coo = spgemm(ea, eb, out_cap="auto", accumulator="auto",
                     plan=plan, check=True)
        ok_plan = np.allclose(np.asarray(coo.to_dense()), ref, atol=1e-2)
        print(f"{name:>18s} {n:6d} {s.nnz_a:8d} {k:4d} "
              f"{wall:8.1f} {lat:9.2f} {en:9.2f} "
              f"{plan.backend:>8s}/{plan.out_cap:<5d}  "
              f"{'✓' if ok and ok_plan else '✗'}")
        assert ok and ok_plan, name
    print("\nall 16 validated against scipy/numpy oracle")

    # Distributed: the sparse-native ring engine, when this host has a mesh
    # (fake one with XLA_FLAGS=--xla_force_host_platform_device_count=8).
    n_dev = len(jax.devices())
    if n_dev > 1:
        from repro import make_dist_plan
        rng = np.random.default_rng(0)
        n = 128
        a = ((rng.random((n, n)) < 0.05)
             * rng.standard_normal((n, n))).astype(np.float32)
        at = a.T.copy()
        ea = ell_rows_from_dense(jnp.array(a), max(1, int((a != 0).sum(0).max())))
        eb = ell_cols_from_dense(jnp.array(at), max(1, int((at != 0).sum(1).max())))
        mesh = jax.make_mesh((n_dev,), ("ring",))
        dp = make_dist_plan(ea, eb, n_dev=n_dev)
        coo = spgemm(ea, eb, mesh=mesh, axis="ring", dist_plan=dp, check=True)
        ok = np.allclose(np.asarray(coo.to_dense()), a @ at, atol=1e-2)
        print(f"distributed A·Aᵀ on {n_dev} devices "
              f"({dp.schedule} schedule, {dp.base.backend} accumulator): "
              f"{'✓' if ok else '✗'}")
        assert ok


if __name__ == "__main__":
    main()
