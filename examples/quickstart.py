"""Quickstart: SPLIM structured SpGEMM in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro import (count_products, ell_cols_from_dense, ell_rows_from_dense,
                   hwmodel, spgemm, spgemm_dense)


def main():
    rng = np.random.default_rng(0)
    n, density = 256, 0.05
    a = ((rng.random((n, n)) < density) * rng.standard_normal((n, n))).astype(np.float32)
    b = ((rng.random((n, n)) < density) * rng.standard_normal((n, n))).astype(np.float32)

    # 1. condense: A row-wise ELLPACK (k_a slabs), B column-wise (k_b slabs)
    k_a = int((a != 0).sum(0).max())
    k_b = int((b != 0).sum(1).max())
    ea = ell_rows_from_dense(jnp.array(a), k_a)
    eb = ell_cols_from_dense(jnp.array(b), k_b)
    print(f"A: {n}x{n}, {int((a!=0).sum())} nnz -> {k_a} row slabs")
    print(f"B: {n}x{n}, {int((b!=0).sum())} nnz -> {k_b} col slabs")

    # 2. structured multiply + in-situ-search-style merge -> sorted COO
    coo = spgemm(ea, eb, out_cap=n * n)
    dense = np.asarray(spgemm_dense(ea, eb))
    np.testing.assert_allclose(np.asarray(coo.to_dense()), a @ b, atol=1e-3)
    np.testing.assert_allclose(dense, a @ b, atol=1e-3)
    print(f"C = A@B ok, nnz(C) = {int(coo.nnz())}, output sorted COO ✓")

    # 3. the paper's efficiency story, on these matrices
    valid = int(count_products(ea, eb))
    util = valid / (k_a * k_b * n)
    util_coo = (a != 0).sum() / n ** 2
    print(f"SCCP valid products: {valid}  (NK² bound: {n*k_a*k_b})")
    print(f"array utilization: SPLIM {util:.2%} vs decompressed {util_coo:.2%} "
          f"-> {util/util_coo:.0f}x gain (paper Fig. 16)")

    # 4. PUM cost model (paper Table II hardware)
    s = hwmodel.MatrixStats(
        n=n, nnz_a=int((a != 0).sum()), nnz_b=int((b != 0).sum()),
        k_a=k_a, k_b=k_b, valid_products=valid,
        nnz_c=int(coo.nnz()), sigma=float((a != 0).sum(1).std()))
    t = hwmodel.splim_latency(s)["total"]
    t_coo = hwmodel.coo_splim_latency(s)["total"]
    print(f"modeled SPLIM latency {t*1e6:.1f} µs vs COO-SPLIM {t_coo*1e6:.1f} µs "
          f"({t_coo/t:.1f}x, paper §IV-C)")


if __name__ == "__main__":
    main()
