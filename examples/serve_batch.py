"""Batched serving example: continuous decode over queued requests, plus
the engine's slot-batched sparse SpGEMM lane (submit/flush + stats).

    PYTHONPATH=src python examples/serve_batch.py [--arch granite-moe-3b-a800m]
"""
import argparse
import time

import jax
import numpy as np

from repro import ell_cols_from_dense, ell_rows_from_dense
from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = get_config(args.arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=4, max_new_tokens=args.max_new,
                                    s_max=64))
    rng = np.random.default_rng(0)
    served = 0
    t0 = time.time()
    while served < args.requests:
        nb = min(4, args.requests - served)
        prompts = [rng.integers(3, cfg.vocab, size=int(rng.integers(4, 12)))
                   .astype(np.int32) for _ in range(nb)]
        outs = eng.generate_batch(prompts)
        for o in outs[:1]:
            print(f"  req[{served}]: {len(o)} tokens -> {o[:8]}...")
        served += nb
    s = eng.stats
    print(f"[serve] {s['requests']} requests, {s['tokens']} new tokens in "
          f"{time.time()-t0:.1f}s ({s['tokens']/max(s['decode_s'],1e-9):.1f} "
          f"decode tok/s)")

    # Sparse SpGEMM lane: heterogeneous C = A·B requests batched onto
    # spgemm_coo_numeric_batched slots, structures recycled through the
    # engine's StructureCache across flushes.
    def sparse_pair(seed, n=64, density=0.05):
        r = np.random.default_rng(seed)
        ad = ((r.random((n, n)) < density)
              * r.standard_normal((n, n))).astype(np.float32)
        bd = ((r.random((n, n)) < density)
              * r.standard_normal((n, n))).astype(np.float32)
        k = max(8, int((ad != 0).sum(0).max()), int((bd != 0).sum(1).max()))
        return ell_rows_from_dense(ad, k), ell_cols_from_dense(bd, k)

    pairs = [sparse_pair(i) for i in range(6)]
    rids = [eng.submit_spgemm(a, b) for a, b in pairs]
    results = eng.flush_spgemm()
    for _ in range(2):                    # warm flushes: pure structure hits
        rids = [eng.submit_spgemm(a, b) for a, b in pairs]
        results = eng.flush_spgemm()
    nnz = int(results[rids[0]].ngroups)
    snap = eng.stats()
    print(f"[spgemm] {snap['spgemm_requests']} sparse requests in "
          f"{snap['spgemm_waves']} waves, occupancy "
          f"{snap['spgemm_occupancy']:.2f}, "
          f"{snap['spgemm_latency_s_per_request']*1e3:.2f} ms/request, "
          f"first result nnz={nnz}; structure cache: "
          f"{snap['structure_cache']['hits']} hits / "
          f"{snap['structure_cache']['misses']} misses")


if __name__ == "__main__":
    main()
