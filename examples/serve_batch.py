"""Batched serving example: continuous decode over queued requests.

    PYTHONPATH=src python examples/serve_batch.py [--arch granite-moe-3b-a800m]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-3b-a800m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()
    cfg = get_config(args.arch + "-smoke")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=4, max_new_tokens=args.max_new,
                                    s_max=64))
    rng = np.random.default_rng(0)
    served = 0
    t0 = time.time()
    while served < args.requests:
        nb = min(4, args.requests - served)
        prompts = [rng.integers(3, cfg.vocab, size=int(rng.integers(4, 12)))
                   .astype(np.int32) for _ in range(nb)]
        outs = eng.generate_batch(prompts)
        for o in outs[:1]:
            print(f"  req[{served}]: {len(o)} tokens -> {o[:8]}...")
        served += nb
    s = eng.stats
    print(f"[serve] {s['requests']} requests, {s['tokens']} new tokens in "
          f"{time.time()-t0:.1f}s ({s['tokens']/max(s['decode_s'],1e-9):.1f} "
          f"decode tok/s)")


if __name__ == "__main__":
    main()
