from .pipeline import DataConfig, SyntheticLMDataset, make_host_loader

__all__ = ["DataConfig", "SyntheticLMDataset", "make_host_loader"]
