"""Deterministic synthetic LM data pipeline.

Production shape without external deps: a seeded, *stateless* token stream
(any (step, shard) pair maps to the same batch forever — restart-safe and
elastic-safe by construction), per-host sharding, sequence packing with EOS
boundaries, and a double-buffered prefetcher. The same interface would wrap
a real tokenized corpus; determinism-by-index is the property checkpoints
rely on (resume at step k ⇒ identical remaining stream, even on a different
host count).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    eos_id: int = 2
    # synthetic stream structure: zipf unigrams + short copy motifs so the
    # loss actually decreases (pure uniform noise has no learnable signal)
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.5


class SyntheticLMDataset:
    """Stateless map-style dataset: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.host_batch = cfg.global_batch // cfg.n_hosts
        # fixed motif bank (shared across hosts; derived from seed only)
        rng = np.random.default_rng(cfg.seed)
        self._motifs = rng.integers(
            3, cfg.vocab, size=(256, cfg.motif_len)).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        b, s = self.host_batch, cfg.seq_len
        # zipf unigrams clipped to vocab
        toks = rng.zipf(cfg.zipf_a, size=(b, s)).astype(np.int64)
        toks = np.minimum(toks + 2, cfg.vocab - 1).astype(np.int32)
        # plant copyable motifs
        n_spots = max(1, s // (4 * cfg.motif_len))
        for i in range(b):
            if rng.random() < cfg.motif_prob:
                ids = rng.integers(0, len(self._motifs), size=n_spots)
                pos = rng.integers(0, max(1, s - cfg.motif_len), size=n_spots)
                for m, p in zip(ids, pos):
                    toks[i, p:p + cfg.motif_len] = self._motifs[m]
        # sequence packing boundaries
        doc_len = rng.integers(s // 4, s, size=b)
        for i in range(b):
            toks[i, :: max(1, int(doc_len[i]))] = cfg.eos_id
        return {"tokens": toks}


def make_host_loader(ds: SyntheticLMDataset, start_step: int = 0,
                     prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Double-buffered background prefetcher over the stateless dataset."""
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            q.put(ds.batch(step))
            step += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass

    return _Iter()
