from .fault import FaultTolerantStep, StragglerDetector, retry_with_backoff
from .trainer import Trainer, TrainerConfig

__all__ = ["FaultTolerantStep", "StragglerDetector", "retry_with_backoff",
           "Trainer", "TrainerConfig"]
