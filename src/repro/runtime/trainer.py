"""Training loop: checkpoint/restart, fault tolerance, metrics.

The loop is deliberately thin: everything heavy is inside the single jitted
train_step; the host side does data feeding, timing, checkpointing, and the
fault-tolerance wrappers. Restart-safety comes from (stateless data ×
atomic checkpoints): `Trainer.run()` resumed from step k reproduces the
exact stream it would have seen.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLMDataset
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init
from repro.launch.steps import make_train_step

from .fault import FaultTolerantStep


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128


class Trainer:
    def __init__(self, model: Model, tcfg: TrainerConfig,
                 opt_cfg: Optional[AdamWConfig] = None,
                 extra_batch_fn: Optional[Callable[[int], Dict]] = None):
        self.model = model
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep_n=tcfg.keep_n)
        self.data = SyntheticLMDataset(DataConfig(
            vocab=model.cfg.vocab, seq_len=tcfg.seq_len,
            global_batch=tcfg.global_batch, seed=tcfg.seed))
        self.extra_batch_fn = extra_batch_fn
        self._jit_step = jax.jit(make_train_step(model, self.opt_cfg),
                                 donate_argnums=(0, 1))
        self.history: list = []

    def _batch(self, step: int) -> Dict[str, Any]:
        batch = {k: jax.numpy.asarray(v)
                 for k, v in self.data.batch(step).items()}
        if self.extra_batch_fn:
            batch.update(self.extra_batch_fn(step))
        return batch

    def init_state(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = self.model.init(rng)
        return params, adamw_init(params)

    def run(self, resume: bool = True) -> Dict[str, Any]:
        params, opt_state = self.init_state()
        start = 0
        if resume and self.ckpt.latest_step() is not None:
            step = self.ckpt.latest_step()
            params, opt_state, extra = self.ckpt.restore(step, params, opt_state)
            start = extra.get("next_step", step)
            print(f"[trainer] resumed from checkpoint step {step}", flush=True)

        def on_preempt(_):
            print("[trainer] preemption notice — checkpointing", flush=True)

        ft_step = FaultTolerantStep(self._jit_step, on_preempt=on_preempt)
        t_last = time.time()
        for step in range(start, self.tcfg.steps):
            batch = self._batch(step)
            params, opt_state, metrics = ft_step(params, opt_state, batch)
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps - 1:
                loss = float(metrics["loss"])
                dt = time.time() - t_last
                t_last = time.time()
                self.history.append({"step": step, "loss": loss})
                print(f"[trainer] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.2f}s)", flush=True)
            if (step + 1) % self.tcfg.ckpt_every == 0 or ft_step.preempted:
                self.ckpt.save(step + 1, params, opt_state,
                               extra={"next_step": step + 1})
                if ft_step.preempted:
                    print("[trainer] exiting after preemption save", flush=True)
                    break
        return {"params": params, "opt_state": opt_state,
                "history": self.history,
                "straggler": ft_step.detector.is_straggler}
