"""Fault-tolerance primitives: retries, straggler detection, preemption.

On a 1000+-node fleet the failure model is: (a) transient device/runtime
errors → retry the step from the last good state; (b) slow nodes → detect
via per-step timing statistics and flag for the scheduler to re-mesh;
(c) preemption notices → checkpoint immediately and exit cleanly. All three
are host-side wrappers around the jitted step, so they add zero cost to the
compiled program.
"""
from __future__ import annotations

import signal
import time
from collections import deque
from typing import Callable, Optional


def retry_with_backoff(fn: Callable, max_retries: int = 3,
                       base_delay: float = 0.5,
                       retriable=(RuntimeError,)):
    """Wrap a step callable: transient failures retry with exp backoff."""
    def wrapped(*args, **kwargs):
        attempt = 0
        while True:
            try:
                return fn(*args, **kwargs)
            except retriable as e:
                attempt += 1
                if attempt > max_retries:
                    raise
                delay = base_delay * (2 ** (attempt - 1))
                print(f"[fault] step failed ({e!r}); retry {attempt}/"
                      f"{max_retries} in {delay:.1f}s", flush=True)
                time.sleep(delay)
    return wrapped


class StragglerDetector:
    """EWMA + robust-sigma step-time monitor.

    A step slower than mean + k·sigma is flagged; persistent flags mark this
    host a straggler (the launcher can then request a re-mesh / hot spare).
    """

    def __init__(self, window: int = 64, k_sigma: float = 4.0,
                 persistent: int = 8):
        self.times = deque(maxlen=window)
        self.k = k_sigma
        self.persistent = persistent
        self.flags = 0
        self.is_straggler = False

    def record(self, step_time: float) -> bool:
        import numpy as np
        flagged = False
        if len(self.times) >= 8:
            arr = np.asarray(self.times)
            med = float(np.median(arr))
            mad = float(np.median(np.abs(arr - med))) + 1e-9
            if step_time > med + self.k * 1.4826 * mad:
                flagged = True
        self.times.append(step_time)
        self.flags = self.flags + 1 if flagged else 0
        if self.flags >= self.persistent:
            self.is_straggler = True
        return flagged


class FaultTolerantStep:
    """Composes retry + straggler tracking + preemption-checkpoint around a
    compiled step function."""

    def __init__(self, step_fn: Callable, on_preempt: Optional[Callable] = None,
                 max_retries: int = 3):
        self._raw = step_fn
        self._step = retry_with_backoff(step_fn, max_retries=max_retries)
        self.detector = StragglerDetector()
        self._preempted = False
        self._on_preempt = on_preempt
        try:
            signal.signal(signal.SIGTERM, self._handle)
        except ValueError:
            pass   # not on main thread (tests)

    def _handle(self, signum, frame):
        self._preempted = True

    @property
    def preempted(self) -> bool:
        return self._preempted

    def __call__(self, *args, **kwargs):
        t0 = time.time()
        out = self._step(*args, **kwargs)
        self.detector.record(time.time() - t0)
        if self._preempted and self._on_preempt is not None:
            self._on_preempt(out)
        return out
