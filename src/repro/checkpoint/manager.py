"""Fault-tolerant checkpointing: atomic, sharded, resharding-on-restore.

Layout (one directory per step):
    <root>/step_000100.tmp/...      (written first)
    <root>/step_000100/             (atomic rename after fsync)
        manifest.json               leaf paths, shapes, dtypes, mesh shape
        shard_<host>.npz            this host's param/opt leaves

Restore is *elastic*: leaves are saved unsharded per-leaf (host 0 of each
replica group writes), so a checkpoint taken on a 16×16 mesh restores onto
any mesh — the new sharding is applied at load. Designed so a preempted /
resized job resumes with only the manifest as coordination state.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, root: str, keep_n: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n

    # -- save ----------------------------------------------------------------
    def save(self, step: int, params: Any, opt_state: Any,
             extra: Optional[dict] = None) -> Path:
        tmp = self.root / f"step_{step:08d}.tmp"
        final = self.root / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        flat_p = _flatten(params)
        flat_o = _flatten(opt_state)
        arrays = {f"params/{k}": np.asarray(v) for k, v in flat_p.items()}
        arrays.update({f"opt/{k}": np.asarray(v) for k, v in flat_o.items()})
        np.savez(tmp / "shard_0.npz", **arrays)

        manifest = {
            "step": step,
            "time": time.time(),
            "extra": extra or {},
            "leaves": {k: {"shape": list(np.shape(v)),
                           "dtype": str(np.asarray(v).dtype)}
                       for k, v in arrays.items()},
        }
        mpath = tmp / "manifest.json"
        mpath.write_text(json.dumps(manifest, indent=1))
        with open(mpath) as f:      # fsync before the atomic publish
            os.fsync(f.fileno())
        os.replace(tmp, final)      # atomic: either fully there or not at all
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for p in self.root.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue            # incomplete write — ignored by design
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, params_like: Any, opt_like: Any,
                shardings: Optional[Tuple[Any, Any]] = None):
        """Restore into the structure of (params_like, opt_like); apply new
        shardings if given (elastic restore onto a different mesh)."""
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "shard_0.npz")

        def rebuild(tree, prefix, shard_tree):
            flat = _flatten(tree)
            shard_flat = _flatten(shard_tree) if shard_tree is not None else None
            leaves, treedef = jax.tree_util.tree_flatten(tree)
            keys = list(flat.keys())
            out = []
            for key in keys:
                arr = data[f"{prefix}/{key}"]
                like = flat[key]
                arr = arr.astype(like.dtype)
                if shard_flat is not None:
                    out.append(jax.device_put(arr, shard_flat[key]))
                else:
                    out.append(jax.numpy.asarray(arr))
            return jax.tree_util.tree_unflatten(treedef, out)

        p_sh, o_sh = shardings if shardings else (None, None)
        params = rebuild(params_like, "params", p_sh)
        opt = rebuild(opt_like, "opt", o_sh)
        return params, opt, manifest["extra"]
