"""Version-portability layer over the JAX APIs SPLIM depends on.

The repo targets a JAX floor of 0.4.37 (see pyproject.toml) but is written
against the modern API surface. Three APIs moved or changed semantics across
the 0.4 → 0.5+ boundary, so every call site goes through this module instead
of `jax.*` directly:

  * ``shard_map``  — top-level ``jax.shard_map`` (with ``check_vma``) on
    modern JAX; ``jax.experimental.shard_map.shard_map`` (with ``check_rep``)
    on 0.4.x. On the legacy path the static replication checker predates
    ``pvary`` — programs written against the varying-manual-axes discipline
    cannot express their annotations there — so we run it unchecked
    (``check_rep=False``); numerics are identical either way.
  * ``pvary``      — marks a replicated value as device-varying for the VMA
    checker. 0.4.x infers replication instead of requiring annotations, so
    the legacy implementation is the identity.
  * ``optimization_barrier`` — always differentiable here. 0.4.x only
    defines the primal rule (``NotImplementedError`` under ``jax.grad``), so
    we wrap it in a ``jax.custom_vjp`` that applies the barrier to both the
    primal and the cotangent. Applying it on the backward pass is not just a
    workaround: the barrier exists to pin per-iteration consumption of the
    remat-saved scan carry (models/transformer.py), and the saved-activation
    reads it guards happen *in the backward loop* — barriering the cotangent
    keeps XLA from hoisting a whole-stack fp32 convert out of exactly that
    loop (the 16.5 GiB/device regression noted there).

Policy: new JAX APIs used anywhere in src/ must either exist on the floor
version or be routed through here with an equivalent legacy realization.
"""
from __future__ import annotations

import jax

JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:3])

_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_PVARY = hasattr(jax.lax, "pvary")


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if _HAS_TOPLEVEL_SHARD_MAP:
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
        kwargs = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kwargs)
else:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
        del check_vma  # VMA annotations are inexpressible pre-pvary
        return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)

shard_map.__doc__ = """Map a function over shards of a mesh.

Portable front-end for ``jax.shard_map`` (modern) /
``jax.experimental.shard_map.shard_map`` (0.4.x). ``check_vma`` is honoured
where the installed JAX supports it and dropped otherwise."""


# ---------------------------------------------------------------------------
# pvary
# ---------------------------------------------------------------------------

if _HAS_PVARY:
    def pvary(x, axis_name):
        """Mark ``x`` as varying over ``axis_name`` for the VMA checker."""
        return jax.lax.pvary(x, axis_name)
else:
    def pvary(x, axis_name):
        """Legacy no-op: 0.4.x shard_map infers replication, no annotation."""
        del axis_name
        return x


# ---------------------------------------------------------------------------
# axis_size
# ---------------------------------------------------------------------------

if hasattr(jax.lax, "axis_size"):
    def axis_size(axis_name) -> int:
        """Size of a mapped mesh axis (modern ``jax.lax.axis_size``)."""
        return jax.lax.axis_size(axis_name)
else:
    def axis_size(axis_name) -> int:
        """Legacy: ``psum(1, axis)`` constant-folds to the concrete size."""
        return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# cost_analysis
# ---------------------------------------------------------------------------

def cost_analysis(compiled):
    """Normalized ``Compiled.cost_analysis()``: one properties dict or None.

    Modern JAX returns a single dict; 0.4.x returns a list with one dict
    per device program. Callers always want the flat dict.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost


# ---------------------------------------------------------------------------
# optimization_barrier (differentiable)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def optimization_barrier(x):
    """`jax.lax.optimization_barrier` with a VJP on every JAX version.

    The barrier is applied in both the primal and the cotangent pass so the
    scheduling pin survives differentiation (see module docstring).
    """
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)
