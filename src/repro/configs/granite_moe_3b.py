"""granite-moe-3b-a800m [moe] — hf:ibm-granite (granite-3.0 MoE family).

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 40 experts top-8 (assignment's explicit "MoE 40e top-8" field).
SPLIM ELLPACK dispatch is the technique-representative path here.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_head=64,
    d_ff=0,              # all-MoE FFN
    vocab=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512,
                  dispatch="sort"),   # SPLIM sort dispatch (§Perf cell A)
    remat="full",
)
