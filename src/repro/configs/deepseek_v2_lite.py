"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512 (arXiv:2405.04434).

27L d_model=2048 16H, MoE 64 routed experts top-6 + 2 shared, per-expert
d_ff=1408 (assignment's explicit "MoE 64e top-6" field), vocab=102400.
Layer 0 keeps a dense FFN (d_ff=10944), per the published architecture.
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,       # MLA: all heads share the latent KV
    d_head=128,
    d_ff=10944,          # dense FFN of layer 0
    vocab=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
                  first_dense_layers=1,
                  dispatch="sort"),  # SPLIM sort dispatch (§Perf cell B)
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64, nope_head_dim=128,
                  v_head_dim=128),
    remat="full",
)
