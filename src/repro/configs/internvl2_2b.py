"""internvl2-2b [vlm] — InternViT stub + InternLM2-1.8B backbone
(arXiv:2404.16821).

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553; the vision frontend
is a STUB: input_specs() provides (B, 256, d_model) patch embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab=92553,
    n_vision_tokens=256,
    rope_theta=1e6,
    remat="full",
)
