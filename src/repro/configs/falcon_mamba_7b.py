"""falcon-mamba-7b [ssm] — mamba-1, attention-free (arXiv:2410.05355).

64L d_model=4096 vocab=65024, ssm_state=16, expand=2 (d_inner=8192),
d_conv=4. Sub-quadratic: runs the long_500k cell.
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    remat="full",
)
