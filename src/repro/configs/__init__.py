"""Architecture registry: --arch <id> resolution for launchers & tests."""
from . import base
from .base import ModelConfig, SHAPES, ShapeCase, applicable_shapes, get_shape

from .mistral_large_123b import CONFIG as _mistral
from .qwen15_110b import CONFIG as _qwen15
from .qwen2_05b import CONFIG as _qwen2
from .yi_34b import CONFIG as _yi
from .falcon_mamba_7b import CONFIG as _falcon_mamba
from .granite_moe_3b import CONFIG as _granite
from .deepseek_v2_lite import CONFIG as _deepseek
from .whisper_medium import CONFIG as _whisper
from .recurrentgemma_9b import CONFIG as _rgemma
from .internvl2_2b import CONFIG as _internvl

ARCHS = {c.name: c for c in [
    _mistral, _qwen15, _qwen2, _yi, _falcon_mamba,
    _granite, _deepseek, _whisper, _rgemma, _internvl,
]}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return ARCHS[name[: -len("-smoke")]].reduced()
    return ARCHS[name]


__all__ = ["ARCHS", "get_config", "ModelConfig", "SHAPES", "ShapeCase",
           "applicable_shapes", "get_shape", "base"]
