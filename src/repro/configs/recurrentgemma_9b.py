"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 1:2 (arXiv:2402.19427).

38L d_model=4096 16H (MQA kv=1, head_dim=256) d_ff=12288 vocab=256000,
pattern (rec, rec, attn) with window 2048. Sub-quadratic: runs long_500k.
"""
from .base import GriffinConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab=256000,
    griffin=GriffinConfig(pattern=("rec", "rec", "attn"), lru_width=4096,
                          window=2048, conv_width=4),
    remat="full",
)
