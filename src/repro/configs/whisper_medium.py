"""whisper-medium [audio] — enc-dec, conv frontend stubbed (arXiv:2212.04356).

24 encoder + 24 decoder layers, d_model=1024 16H (MHA) d_ff=4096
vocab=51865; frontend stub provides (B, 1500, d_model) frame embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    n_encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab=51865,
    qkv_bias=True,
    remat="full",
)
