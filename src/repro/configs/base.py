"""Config system: one dataclass covering every assigned architecture family.

Each ``src/repro/configs/<arch>.py`` exports ``CONFIG`` (the exact published
configuration) built from this dataclass. ``reduced()`` derives the tiny
same-family variant used by CPU smoke tests. ``SHAPES`` defines the assigned
input-shape set (LM-family: seq_len × global_batch, with decode/long shapes
lowering ``serve_step``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_dense_layers: int = 0       # deepseek: layer 0 keeps a dense FFN
    capacity_factor: float = 1.25
    dispatch: str = "ellpack"         # 'ellpack' (one-hot) | 'sort' | 'spmm'
    xe_shard: str = "both"            # sort-dispatch buffer sharding: both|batch|expert
    comm: str = "all_to_all"          # 'all_to_all' | 'ring' (SPLIM ring)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 = full-rank Q (v2-lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                  # 0 -> ceil(d_model/16)
    chunk: int = 256                  # chunked-scan block length


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")   # RG 1 attn : 2 rec
    lru_width: int = 0                # 0 -> d_model
    window: int = 2048                # local attention window
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_window: int = 0             # 0 = full causal attention
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    griffin: Optional[GriffinConfig] = None
    # enc-dec (whisper): encoder layer count; frontend provides embeddings
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30 s of audio -> 1500 frames
    # vlm: vision stub
    n_vision_tokens: int = 0
    # numerics / scan
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: str = "none"              # none | full | dots  (activation ckpt)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SSM / bounded-window hybrids)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True   # all assigned archs are decoders or enc-dec

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small_moe = None
        if self.moe:
            small_moe = dataclasses.replace(
                self.moe, n_experts=min(8, self.moe.n_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=64,
                first_dense_layers=min(1, self.moe.first_dense_layers))
        small_mla = dataclasses.replace(
            self.mla, kv_lora_rank=32, rope_head_dim=8, nope_head_dim=16,
            v_head_dim=16) if self.mla else None
        small_ssm = dataclasses.replace(
            self.ssm, d_state=4, chunk=16) if self.ssm else None
        small_griffin = dataclasses.replace(
            self.griffin, lru_width=64, window=8) if self.griffin else None
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, len(self.griffin.pattern) if self.griffin else 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=128,
            vocab=256,
            moe=small_moe, mla=small_mla, ssm=small_ssm, griffin=small_griffin,
            n_encoder_layers=2 if self.n_encoder_layers else 0,
            encoder_seq=16 if self.n_encoder_layers else 1500,
            n_vision_tokens=8 if self.n_vision_tokens else 0,
            param_dtype="float32", compute_dtype="float32",
        )

    def n_params(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = self.ssm.expand * d
            dt = self.ssm.dt_rank or -(-d // 16)
            per = (d * di * 2            # in_proj
                   + di * self.ssm.d_conv
                   + di * (dt + 2 * self.ssm.d_state)
                   + dt * di + di * d + di * self.ssm.d_state + di)
            return emb + L * per
        attn = d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.mla:
            m = self.mla
            q_dim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
            attn = d * q_dim + d * (m.kv_lora_rank + m.rope_head_dim) \
                + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim) \
                + self.n_heads * m.v_head_dim * d
        if self.moe:
            moe_ff = 3 * d * self.moe.d_ff_expert
            per = attn + moe_ff * (self.moe.n_experts + self.moe.n_shared) \
                + d * self.moe.n_experts
            dense_ff = 3 * d * self.d_ff if self.d_ff else 0
            return emb + L * per + self.moe.first_dense_layers * (dense_ff - moe_ff * (self.moe.n_experts + self.moe.n_shared))
        ff = 3 * d * self.d_ff
        n_enc = self.n_encoder_layers
        cross = d * (self.n_heads * hd) * 2 if n_enc else 0
        return emb + (L + n_enc) * (attn + ff) + L * cross

    def active_params(self) -> int:
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.mla:
            m = self.mla
            q_dim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
            attn = d * q_dim + d * (m.kv_lora_rank + m.rope_head_dim) \
                + m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim) \
                + self.n_heads * m.v_head_dim * d
        moe_ff = 3 * d * self.moe.d_ff_expert
        per = attn + moe_ff * (self.moe.top_k + self.moe.n_shared)
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return emb + L * per


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES = (
    ShapeCase("train_4k", 4_096, 256, "train"),
    ShapeCase("prefill_32k", 32_768, 32, "prefill"),
    ShapeCase("decode_32k", 32_768, 128, "decode"),
    ShapeCase("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeCase:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable_shapes(cfg: ModelConfig):
    """The assigned cells for this arch (DESIGN.md §4 skip rules)."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue   # pure full-attention arch — documented skip
        out.append(s)
    return tuple(out)
