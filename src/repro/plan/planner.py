"""Workload-adaptive accumulation planning for SPLIM SpGEMM.

SPLIM's thesis splits SpGEMM into a *structured* multiply (SCCP — always the
same dataflow) and an *unstructured* accumulation, and the accumulation is
where one size does not fit all: the SpGEMM literature picks sort-, bin-, or
hash-based accumulators per matrix (Gu et al. propagation blocking; Nagasaka
et al. hash vs heap on KNL). This module is that selection step for our six
backends:

  sort    — global ``jax.lax.sort`` + segmented sum (core/accumulate)
  tiled   — multi-tile bitonic merge tree (kernels/bitonic_merge)
  bucket  — propagation blocking: bin by row range, per-bucket bitonic
            (kernels/radix_bucket)
  hash    — per-row-block open-addressing tables (kernels/hash_accum)
  stream  — slab-scan multiply→compact→merge (core/streaming): the only
            backend that never materializes the (k_a, n, k_b) product
            stream; its intermediate is O(n·k_b + stream_cap)
  search  — the paper's in-situ-search accumulation (kernels/insitu_search):
            key-only emission of the sorted unique coordinates, then every
            product aligned against that list — values are never sorted,
            so the win grows with the duplicate ratio S / nnz(C)

The model is also **memory-aware**: every backend's modeled intermediate
bytes go into ``Plan.est`` (``interm_*`` — the materialized un-accumulated
product lanes, the quantity SpGEMM is bound by per Liu & Vinter / Nagasaka
et al.), and when the op-count winner's intermediate exceeds
``mem_budget`` bytes the planner overrides it with ``'stream'``, whose
intermediate does not grow with ``k_a``.

``make_plan`` runs the symbolic phase (plan/symbolic) on concrete operands,
derives ``out_cap`` and every backend's blocking sizes from *exact*
histograms (so the planned bucket/hash paths can never drop products), scores
the backends with an operation-count cost model fed by ``hwmodel.MatrixStats``
(``hwmodel.stats_from_ell`` is the ELL-side variant of ``stats_from_scipy``),
and returns a frozen ``Plan`` whose fields are all Python ints — the plan
itself is jit/vmap-compatible even though planning is a host-side step.

Cost model: all backends first pay the SCCP stream ``S`` (padded to a power
of two); they differ in what they do per stream element and in how much of
the work runs inside Pallas networks. Off-TPU the Pallas kernels execute in
interpreter mode (orders of magnitude slower than XLA's fused sort), so the
model carries an interpreter penalty on Pallas terms — on CPU hosts the
planner therefore honestly prefers ``sort``, while the op counts govern on
real TPUs. ``benchmarks/microbench.accum_backends_micro`` validates the
choice against measured times.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.formats import EllCols, EllRows
from repro.core.hwmodel import MatrixStats, splim_latency, stats_from_ell
from repro.kernels.bitonic_merge import next_pot as _pot
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs

from . import symbolic

BACKENDS = ("sort", "tiled", "bucket", "hash", "stream", "search")

# Cost-model constants (relative vector-op units per element).
XLA_SORT_C = 1.0        # XLA fused sort, per element per log2 level
CE_C = 1.0              # one bitonic compare-exchange step
BIN_C = 2.0             # binning scan + scatter, per element
PROBE_C = 3.0           # one probe round: 2 gathers + 1 scatter-min
SEGSUM_C = 1.0          # segment_sum per element
INTERPRET_PENALTY = 50.0   # Pallas interpret-mode slowdown off-TPU
# 'sort' pays 12 B/lane over three operands with a two-key comparator; the
# streaming engine's packed single-key tile sorts move 8 B/lane with a
# scalar comparator (STREAM_SORT_C scales its per-element unit down).
SORT_TRAFFIC = 1.5
STREAM_SORT_C = 0.5
# 'search' sorts KEYS ONLY for its emission phase (4 B/lane, scalar
# comparator — no value lanes ride the network), then aligns each product
# against the nnz(C)-long unique list (log2(nnz_C) levels, not log2(S)).
SEARCH_SORT_C = 0.4
ALIGN_C = 0.5
# Fixed per-scan-step floor of the streaming engine (dispatch + carry +
# compaction bookkeeping), in the same per-element units — measured ≈ a
# few hundred µs off-TPU. This is what the planner's stream_group
# amortizes; it also keeps 'stream' from being chosen on tiny streams
# where the monolithic sort is dispatch-free.
SCAN_STEP_C = 16384.0
# Off-TPU a scan step's tile should be big enough to amortize SCAN_STEP_C:
# stream_group targets this many lanes per tile, subject to the streamed
# intermediate staying ≥ STREAM_INTERM_MARGIN× under the materialized
# stream (the whole point of streaming — and the bench's evidence gate).
STREAM_TILE_TARGET = 32768
STREAM_INTERM_MARGIN = 4.0

# Default intermediate-bytes budget before the planner forces 'stream':
# 1 GiB of materialized product lanes comfortably fits HBM/host RAM for the
# toy suites, while genuinely large k_a·n·k_b streams blow past it.
DEFAULT_MEM_BUDGET = 1 << 30


def _net_cost(n: int, length: int) -> float:
    """Compare-exchange count of a full bitonic sort of ``n`` elements in
    power-of-2 rows of ``length`` (all rows ride one network)."""
    lt = max(1, int(math.log2(max(2, length))))
    return n * lt * (lt + 1) / 2 * CE_C


@dataclasses.dataclass(frozen=True)
class Plan:
    """A fully static accumulation plan (safe to close over under jit/vmap).

    ``fp`` is the sparsity fingerprint of the operands the plan was sized
    for (``plan.structure.fingerprint``); ``spgemm_coo(plan=)`` validates it
    against the actual operands and raises on mismatch instead of silently
    producing garbage or poisoned overflow. ``dataclasses.replace(plan,
    fp=None)`` opts a plan out of validation for deliberate reuse across
    similarly-sparse patterns (pair with ``slack`` > 1 headroom). ``stats``
    and ``est`` are advisory (excluded from equality/hash so plans stay
    usable as static jit aux data).
    """

    backend: str                      # one of BACKENDS
    out_cap: int
    tile: int = 4096                  # 'tiled' merge-tree tile
    stream_cap: Optional[int] = None  # 'stream' per-tile compaction width
    stream_group: int = 1             # 'stream' A slabs per scan step
    # Blocking sizes: make_plan fills all four from exact histograms. Leaving
    # them None (hand-built plans) resolves to the ops-layer safe default —
    # ONE stream-sized bucket/table, not an n-way split of stream-sized ones.
    n_buckets: Optional[int] = None   # 'bucket' row-range partitions
    bucket_cap: Optional[int] = None  # per-bucket slots (pow2)
    n_blocks: Optional[int] = None    # 'hash' row-range partitions
    block_cap: Optional[int] = None   # per-block table slots (pow2)
    max_probes: Optional[int] = None  # None = full probe cycle (never spuriously drops)
    fp: Optional[str] = None          # operand sparsity fingerprint
    stats: Optional[MatrixStats] = dataclasses.field(default=None,
                                                     compare=False)
    est: Dict[str, float] = dataclasses.field(default_factory=dict,
                                              compare=False)


def _backend_costs(s: MatrixStats, stream_pot: int, tile: int,
                   n_buckets: int, bucket_cap: int,
                   n_blocks: int, block_cap: int,
                   n_steps: int, tile_lanes: int, stream_cap: int,
                   buf_cap: int, on_tpu: bool) -> Dict[str, float]:
    S = float(stream_pot)
    ls = max(1.0, math.log2(S))
    pal = 1.0 if on_tpu else INTERPRET_PENALTY

    cost = {"sort": SORT_TRAFFIC * XLA_SORT_C * S * ls}

    lt = math.log2(tile)
    tree_ce = S * (lt * (lt + 1) / 2 + sum(range(int(lt) + 1, int(ls) + 1)))
    cost["tiled"] = pal * tree_ce * CE_C

    cost["bucket"] = (pal * (BIN_C * S * (1 + n_buckets / 64)
                             + _net_cost(n_buckets * bucket_cap, bucket_cap)))

    load = min(0.95, s.nnz_c / max(1, n_blocks * block_cap))
    probes = 1.0 / max(0.05, 1.0 - load)
    cost["hash"] = (PROBE_C * S * probes + SEGSUM_C * S
                    + pal * _net_cost(n_blocks * block_cap, block_cap))

    # stream: n_steps sequential steps of (group-tile packed sort, merge
    # with the 2·buf_cap buffer pair) plus the fixed per-step dispatch
    # floor (which also covers the cheap compactions). The tile sort is
    # XLA's fused sort off-TPU and the fused in-VMEM network on TPU —
    # never interpret-mode Pallas, so no interpreter penalty applies.
    t = float(_pot(tile_lanes))
    ltile = max(1.0, math.log2(max(2.0, t)))
    tile_sort = (_net_cost(t, int(t)) if on_tpu
                 else STREAM_SORT_C * XLA_SORT_C * t * ltile)
    mrg = float(2 * buf_cap)
    merge = CE_C * mrg * (math.log2(mrg) + 1)
    cost["stream"] = n_steps * (tile_sort + merge + SCAN_STEP_C)

    # search: key-only emission sort + per-product alignment against the
    # nnz(C) unique keys + one segment-sum. Both realizations are compiled
    # (XLA sort/searchsorted off-TPU, the Pallas network/CAM kernel on TPU)
    # so no interpreter penalty applies — the dup ratio S/nnz_C is what
    # moves the alignment term below the full re-sort.
    lu = max(1.0, math.log2(max(2.0, float(s.nnz_c))))
    cost["search"] = (SEARCH_SORT_C * XLA_SORT_C * S * ls
                      + ALIGN_C * S * lu + SEGSUM_C * S)
    return cost


def _stream_interm_bytes(tile_lanes: int, stream_cap: int) -> float:
    """Streaming engine's peak materialized intermediate: the packed
    (key+val, 8 B/lane) sorted tile plus the compacted ``stream_cap``
    lanes. The raw 12 B/lane product tile never materializes — on TPU it
    lives in the fused kernel's VMEM registers, off-TPU the element-wise
    multiply→mask→pack chain fuses into the sort-operand computation."""
    return 8.0 * (_pot(tile_lanes) + stream_cap)


def _backend_interm_bytes(stream_lanes: int, stream_pot: int,
                          tile_lanes: int, stream_cap: int,
                          n_buckets: int, bucket_cap: int,
                          n_blocks: int, block_cap: int,
                          out_cap: int) -> Dict[str, float]:
    """Modeled peak *materialized intermediate* bytes per backend — the
    un-accumulated product lanes alive at once (the SpGEMM working-set
    bound of Liu & Vinter / Nagasaka et al.), not the output buffer all
    backends share via ``out_cap``. Every materialized backend first pays
    the full 12 B/lane (val+row+col) SCCP stream; the packed-key ones add
    an 8 B/lane (key+val) copy, blocking adds its bins/tables. The stream
    backend's intermediate (``_stream_interm_bytes``) is independent of
    ``k_a``."""
    raw = 12.0 * stream_lanes
    packed = 8.0 * stream_pot
    return {
        "sort": raw,
        "tiled": raw + packed,
        "bucket": raw + packed + 8.0 * n_buckets * bucket_cap,
        "hash": raw + packed + 8.0 * n_blocks * block_cap,
        "stream": _stream_interm_bytes(tile_lanes, stream_cap),
        # packed key+val copy, the key-only sorted copy (4 B/lane), and the
        # unique-key list + slot sums the alignment scatters into
        "search": raw + 12.0 * stream_pot + 8.0 * out_cap,
    }


def make_plan(a: EllRows, b: EllCols, *, out_cap: Optional[int] = None,
              backend: Optional[str] = None, exact: bool = True,
              tile: int = 4096, slack: float = 1.0,
              mem_budget: int = DEFAULT_MEM_BUDGET) -> Plan:
    """Symbolic phase + backend selection on concrete (non-traced) operands.

    ``out_cap``/``backend`` pin the respective decision while the planner
    still derives the rest (e.g. ``backend='hash'`` with auto table sizes).
    ``exact=False`` degrades the symbolic phase to the cheap row-flop upper
    bound (sizes stay safe: caps come from product histograms, which
    dominate unique-coordinate histograms). ``mem_budget`` bounds the
    modeled materialized-intermediate bytes: when the op-count winner would
    materialize more, ``'stream'`` (whose intermediate is O(n·k_b), not
    O(k_a·n·k_b)) is chosen instead.
    """
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected {BACKENDS}")
    n_rows, n_cols, n = a.n_rows, b.n_cols, a.n_cols
    if n_rows * n_cols >= 2 ** 31 - 1 and backend not in (None, "sort"):
        raise ValueError(
            f"backend {backend!r} needs packed int32 coordinate keys but the "
            f"output space is {n_rows}x{n_cols}; only 'sort' (unpacked "
            "two-key path) spans it")
    stream = a.k * n * b.k
    stream_pot = _pot(stream)
    on_tpu = jax.default_backend() == "tpu"
    slab_lanes = n * b.k

    # --- symbolic phase -----------------------------------------------------
    # The exact unique-coordinate pass costs one coordinate-only stream sort;
    # run it only when something consumes tight uniques: out_cap sizing, or
    # table sizing for a possible hash backend. Bound-based sizing stays safe
    # (the clipped row-flop bound dominates the true per-row uniques).
    exact = exact and (out_cap is None or backend in (None, "hash"))
    with _obs.span("spgemm.symbolic", backend=backend or "auto", exact=exact,
                   n_rows=n_rows, n_cols=n_cols):
        products_per_row, unique_per_row = symbolic.per_row_counts(
            a, b, exact=exact)
        products_per_row = jax.device_get(products_per_row)   # host sync
        unique_per_row = jax.device_get(unique_per_row)
    nnz_c = int(unique_per_row.sum())
    if out_cap is None:
        cap = -(-int(max(1, nnz_c) * slack) // symbolic.LANE) * symbolic.LANE
        out_cap = max(symbolic.LANE, cap)

    # --- blocking sizes from exact histograms (never-drop guarantee) --------
    n_buckets = min(64, max(2, _pot(stream_pot // 4096)))
    n_blocks = n_buckets
    rpb = -(-n_rows // n_buckets)
    pad = n_buckets * rpb - n_rows
    prod_hist = np.pad(np.asarray(products_per_row),
                       (0, pad)).reshape(n_buckets, rpb).sum(axis=1)
    uniq_hist = np.pad(np.asarray(unique_per_row),
                       (0, pad)).reshape(n_blocks, rpb).sum(axis=1)
    bucket_cap = min(stream_pot, max(128, _pot(int(prod_hist.max()))))
    block_cap = min(stream_pot, max(128, _pot(2 * int(uniq_hist.max()))))
    # stream sizing. stream_cap: per-tile compaction width from the exact
    # per-slab product histogram — a group tile's uniques never exceed its
    # products, which are bounded by group · the largest slab count, so
    # this cap never drops (full-tile fallback when slabs are empty).
    # stream_group: on TPU the fused VMEM kernel wants single slabs; off
    # TPU take the largest group that amortizes the per-step dispatch
    # floor (STREAM_TILE_TARGET lanes) while the streamed intermediate
    # stays ≥ STREAM_INTERM_MARGIN× under the materialized stream.
    max_slab = int(jax.device_get(symbolic.max_slab_products(a, b)))

    def _scap(g: int) -> int:
        return min(_pot(g * slab_lanes), max(128, _pot(g * max_slab)))

    group = 1
    if not on_tpu:
        group = max(1, min(a.k, STREAM_TILE_TARGET // max(1, slab_lanes)))
        while group > 1 and (STREAM_INTERM_MARGIN
                             * _stream_interm_bytes(group * slab_lanes,
                                                    _scap(group))
                             > 12.0 * stream):
            group -= 1
    tile_lanes = group * slab_lanes
    n_steps = -(-a.k // group)
    stream_cap = _scap(group)
    buf_cap = _pot(max(int(out_cap), 128))

    # --- backend selection --------------------------------------------------
    # Pinned backend = sizing-only request: skip the stats pass and the cost
    # model whose output would be discarded (bare spgemm_coo(a, b) pins
    # 'sort' and pays only the symbolic phase above).
    if backend is not None:
        s, est, chosen = None, {}, backend
    else:
        s = stats_from_ell(a, b, nnz_c=nnz_c)
        costs = _backend_costs(s, stream_pot, tile, n_buckets, bucket_cap,
                               n_blocks, block_cap, n_steps, tile_lanes,
                               stream_cap, buf_cap, on_tpu)
        interm = _backend_interm_bytes(stream, stream_pot, tile_lanes,
                                       stream_cap, n_buckets, bucket_cap,
                                       n_blocks, block_cap, int(out_cap))
        chosen = min(costs, key=costs.get)
        # memory-aware override: a winner that must materialize more
        # intermediate bytes than the budget loses to the streaming engine,
        # whose working set does not grow with k_a.
        if interm[chosen] > mem_budget and interm["stream"] < interm[chosen]:
            chosen = "stream"
        if n_rows * n_cols >= 2 ** 31 - 1:
            chosen = "sort"                 # only unpacked keys span the space
        est = {f"cost_{k}": v for k, v in costs.items()}
        est.update({f"interm_{k}": v for k, v in interm.items()})
        est["mem_budget"] = float(mem_budget)
        est["splim_model_s"] = splim_latency(s)["total"]
    from .structure import fingerprint   # lazy: structure imports this module
    fp = fingerprint(a, b)
    if _obs.is_enabled():
        # planner-evidence ledger: est costs now, measured µs arrive from
        # the instrumented accumulate spans keyed by the same fingerprint
        _obs_metrics.record_plan(fp[:12], chosen, est)
        _obs.instant("plan.decision", backend=chosen, out_cap=int(out_cap),
                     pinned=backend is not None)
    return Plan(backend=chosen, out_cap=int(out_cap), tile=tile,
                stream_cap=stream_cap, stream_group=group,
                n_buckets=n_buckets, bucket_cap=bucket_cap,
                n_blocks=n_blocks, block_cap=block_cap, max_probes=None,
                fp=fp, stats=s, est=est)


# ---------------------------------------------------------------------------
# Distributed planning (core/distributed.spgemm_coo_sharded)
# ---------------------------------------------------------------------------

SCHEDULES = ("ring", "cstat", "summa")


def _lane_pad(x: int) -> int:
    return max(symbolic.LANE, -(-int(x) // symbolic.LANE) * symbolic.LANE)


def grid_candidates(n_dev: int):
    """Non-degenerate ``(pr, pc)`` factorizations of ``n_dev`` (both ≥ 2).

    A factorization with a side of 1 degenerates to a 1D schedule — its
    communication is the ring/cstat model, so modeling it as "2D" would
    invent phantom column-traffic savings (the 2-device-mesh bug this
    function exists to prevent). Degenerate grids are therefore never
    candidates for ``schedule='auto'``; an *explicit* ``schedule='summa'``
    on a prime mesh still runs (``best_grid(allow_degenerate=True)``) but
    is modeled with 1D bytes.
    """
    return [(pr, n_dev // pr) for pr in range(2, n_dev)
            if n_dev % pr == 0 and n_dev // pr >= 2]


def best_grid(n_dev: int, k_a: int, k_b: int, *,
              allow_degenerate: bool = False):
    """Least-operand-motion ``(pr, pc)`` grid for a SUMMA-style schedule.

    Per-device operand motion is ``k_a·(pc−1) + k_b·(pr−1)`` slab-lanes
    (A hops along the grid row, B along the grid column), so non-square
    operand widths want non-square grids. Returns ``None`` when no
    non-degenerate factorization exists (prime or 2-device meshes) unless
    ``allow_degenerate`` — then the better of ``(n_dev, 1)`` / ``(1,
    n_dev)`` is returned so an explicit ``schedule='summa'`` still runs.
    """
    cands = grid_candidates(n_dev)
    if not cands:
        if not allow_degenerate:
            return None
        cands = [(n_dev, 1), (1, n_dev)] if n_dev > 1 else [(1, 1)]
    return min(cands, key=lambda g: k_a * (g[1] - 1) + k_b * (g[0] - 1))


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """A fully static distributed-SpGEMM plan (Python ints — safe to close
    over under jit/shard_map). Capacities come from exact per-shard/per-block
    histograms, so a planned run never drops partials:

      local_cap — device-local accumulation width, ≥ the unique coordinates
                  any one device's slab-product stream produces (exact
                  per-shard AND per-grid-cell product counts ∧ global
                  nnz(C) — the max of both histograms, so one plan stays
                  safe under ``dataclasses.replace(dp, schedule=...)``);
      bin_cap   — per-destination COO-exchange bin, ≥ any (device, owner)
                  partial count (bounded by both of the above);
      block_cap — per-owner row-block output width, ≥ the exact block nnz.

    ``(pr, pc)`` is the logical 2D grid the ``'summa'`` schedule factors the
    device axis into (``pr·pc == n_dev``); it is always populated with the
    best factorization so replacing the schedule on an existing plan works.
    """

    schedule: str             # 'ring' | 'cstat' | 'summa' (2D grid)
    n_dev: int
    rows_per_dev: int         # owner(r) = r // rows_per_dev
    local_cap: int
    bin_cap: int
    block_cap: int
    out_cap: int              # final global COO capacity
    base: Plan                # device-local accumulation backend + sizes
    fp: Optional[str] = None  # operand sparsity fingerprint (see Plan.fp)
    pr: int = 1               # 'summa' grid rows (A panels hop along rows)
    pc: int = 1               # 'summa' grid cols (B panels hop along cols)
    est: Dict[str, float] = dataclasses.field(default_factory=dict,
                                              compare=False)


def make_dist_plan(a: EllRows, b: EllCols, *, n_dev: int,
                   schedule: Optional[str] = None,
                   out_cap: Optional[int] = None,
                   backend: Optional[str] = None,
                   tile: int = 4096, slack: float = 1.0) -> DistPlan:
    """Distributed symbolic phase + schedule selection (concrete operands).

    Extends ``make_plan`` across a mesh axis of ``n_dev`` devices: the base
    plan supplies the device-local accumulation backend and the global
    ``out_cap``; per-shard / per-grid-cell product counts and per-row-block
    nnz histograms (plan/symbolic) size the exchange. Schedule choice weighs
    the per-device communication volume (hwmodel-style byte counting, mesh
    size included): the B-stationary ring pays full-B rotation plus an
    owner-binned COO exchange of the partial results, the C-stationary
    schedule pays full A replication instead, and the 2D ``'summa'``
    schedule hops A panels along grid rows and B panels along grid columns
    — ~``1/√p`` of either operand's 1D volume — plus the same COO exchange
    as ``'ring'``. The grid factorization is chosen per operand widths
    (``best_grid``); meshes with no non-degenerate factorization (2 devices,
    primes) fall back to the 1D model and are never auto-picked as 2D.
    ``schedule=`` pins it, otherwise the cheapest wins.
    """
    if schedule is not None and schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; expected {SCHEDULES}")
    if n_dev < 1:
        raise ValueError(f"n_dev must be >= 1, got {n_dev}")
    base = make_plan(a, b, out_cap=out_cap, backend=backend, tile=tile,
                     slack=slack)
    n_rows, n_cols, n = a.n_rows, b.n_cols, a.n_cols
    rpd = -(-n_rows // n_dev)
    block_uniq = np.asarray(jax.device_get(
        symbolic.per_block_nnz(a, b, n_dev)))
    shard_prod = np.asarray(jax.device_get(
        symbolic.per_shard_products(a, b, n_dev)))
    grid = best_grid(n_dev, a.k, b.k, allow_degenerate=True)
    pr, pc = grid
    # cap sizing covers EVERY factorization (incl. both degenerate
    # orientations), not just the chosen grid, so a plan stays never-drop
    # under dataclasses.replace(dp, schedule=..., pr=..., pc=...)
    grid_cell_max = max(
        int(np.asarray(jax.device_get(
            symbolic.per_grid_products(a, b, gr, gc))).max())
        for gr, gc in (grid_candidates(n_dev) or []) + [(1, n_dev)])
    nnz_c = int(block_uniq.sum())
    block_cap = _lane_pad(int(block_uniq.max()))
    # max over BOTH partitions (1D shards, 2D grid cells) so one plan stays
    # never-drop under any schedule it may be replaced into
    local_cap = _lane_pad(min(max(1, nnz_c),
                              max(int(shard_prod.max()), grid_cell_max)))
    # entries device d sends owner o ≤ min(d's local uniques, o's block nnz)
    bin_cap = _lane_pad(min(local_cap, block_cap))
    flops = int(shard_prod.sum())
    # per-device communication bytes (8 B/lane of val+idx operand motion,
    # 12 B/triple COO partial exchange): 'ring' rotates all of B and
    # exchanges partials, 'cstat' rotates B and replicates A, 'summa' hops
    # each operand only along its grid dimension — (pc−1)/p of A plus
    # (pr−1)/p of B — and pays the same partial exchange as 'ring'.
    rotate_b = 8.0 * n * b.k
    exchange = 12.0 * min(nnz_c, max(1, flops // n_dev))
    ring_bytes = rotate_b + exchange
    cstat_bytes = rotate_b + 8.0 * n * a.k
    degenerate = min(pr, pc) < 2
    if degenerate:
        # a 1-wide grid degenerates to a 1D schedule: model it with the 1D
        # bytes so 'auto' can never be lured by phantom column traffic
        summa_bytes = ring_bytes
    else:
        summa_bytes = (8.0 * n * (a.k * (pc - 1) + b.k * (pr - 1)) / n_dev
                       + exchange)
    est = dict(base.est)
    est.update({"ring_comm_bytes": ring_bytes,
                "cstat_comm_bytes": cstat_bytes,
                "summa_comm_bytes": summa_bytes,
                "summa_pr": float(pr), "summa_pc": float(pc),
                "nnz_c": float(nnz_c), "flops": float(flops)})
    if schedule is None:
        schedule = "cstat" if cstat_bytes < ring_bytes else "ring"
        if not degenerate and summa_bytes < est[f"{schedule}_comm_bytes"]:
            schedule = "summa"
    if _obs.is_enabled():
        _obs.instant("plan.dist_decision", schedule=schedule, n_dev=n_dev,
                     pr=pr, pc=pc, ring_comm_bytes=ring_bytes,
                     cstat_comm_bytes=cstat_bytes,
                     summa_comm_bytes=summa_bytes)
    return DistPlan(schedule=schedule, n_dev=n_dev, rows_per_dev=rpd,
                    local_cap=local_cap, bin_cap=bin_cap, block_cap=block_cap,
                    out_cap=base.out_cap, base=base, fp=base.fp,
                    pr=pr, pc=pc, est=est)


def plan_spmm_format(w, candidates=None):
    """Route a pruned dense weight to its SpMM storage format.

    The weights-side twin of ``make_plan``'s accumulation choice: inspects
    the (host-side, one-time) sparsity pattern of a pruned ``(d_in, d_out)``
    weight and returns ``("nm", (n, m))`` when some candidate N:M window
    balances every column's reduction windows — the gather-free
    kernels/nm_spmm.py fast path — or ``("ellpack", None)`` otherwise
    (structured SpMM via ``spmm_dense_ell`` / kernels/ell_spmm.py, which
    tolerates arbitrary patterns at worst-row slab width). Bit-identical
    results either way; models/sparse.SparseLinear consumes the decision.
    """
    from repro.core.nm import NM_CANDIDATES, detect_nm
    shape = detect_nm(w, NM_CANDIDATES if candidates is None else candidates)
    if _obs.is_enabled():
        _obs.instant("plan.spmm_format",
                     fmt="nm" if shape else "ellpack",
                     nm=str(shape) if shape else "")
    if shape is not None:
        return ("nm", shape)
    return ("ellpack", None)
