"""Fingerprint-keyed structure cache: first call pays, the fleet rides free.

:class:`StructureCache` fronts ``plan.structure.make_structure`` with an
in-process LRU keyed by the operands' sparsity fingerprint (index planes +
shapes + value dtype, values excluded — see ``plan.structure.fingerprint``),
so repeated multiplies over the same pattern (GNN layers, iterative solvers,
serve-time sparse FFN applies) run the symbolic phase once and the numeric
phase (``core.spgemm.spgemm_coo_numeric``) forever after.

Three optional layers on top of the LRU:

  * **Disk persistence** (``cache_dir=``): every built structure is written
    as ``<fingerprint>.npz`` (coordinate arrays + a JSON metadata blob
    carrying the Plan/DistPlan statics), so a fresh process — or a fleet of
    them sharing a filesystem — warm-starts without re-running the symbolic
    phase. Writes are atomic (tmp + rename); a corrupt or stale file is
    treated as a miss, never an error.
  * **Measured autotune** (``autotune=True``): on first build the planner's
    cost-model backend choice is validated against short timed probes of
    every candidate backend on the real operands; the measured winner's plan
    is cached (probe timings recorded in ``plan.est['autotune_us']``).
  * **Stats** (:meth:`StructureCache.stats`): hit / miss / eviction /
    disk-hit / autotune counters for capacity planning and tests.

Thread-safe: lookups and LRU mutation hold an internal lock; the expensive
build runs outside it (concurrent first calls on the same pattern may both
build — idempotent, last insert wins).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax
import numpy as np

from repro.core.formats import EllCols, EllRows
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs

from .planner import BACKENDS, DistPlan, Plan
from .structure import SpgemmStructure, fingerprint, make_structure

_FORMAT_VERSION = 1


def _plan_to_dict(plan: Plan) -> dict:
    d = {f.name: getattr(plan, f.name) for f in dataclasses.fields(plan)}
    d.pop("stats", None)  # MatrixStats is derivable, not worth serializing
    try:
        json.dumps(d.get("est"))
    except (TypeError, ValueError):
        d["est"] = {}
    return d


def _dist_plan_to_dict(dp: DistPlan) -> dict:
    d = {f.name: getattr(dp, f.name) for f in dataclasses.fields(dp)}
    d["base"] = _plan_to_dict(dp.base)
    try:
        json.dumps(d.get("est"))
    except (TypeError, ValueError):
        d["est"] = {}
    return d


def _plan_from_dict(d: dict) -> Plan:
    return Plan(**d)


def _dist_plan_from_dict(d: dict) -> DistPlan:
    d = dict(d)
    d["base"] = _plan_from_dict(d["base"])
    return DistPlan(**d)


class StructureCache:
    """LRU cache of :class:`~repro.plan.structure.SpgemmStructure` entries
    keyed by sparsity fingerprint (see module docstring).

    ``capacity`` bounds the in-memory entry count (least-recently-used
    evicted first; disk copies, if enabled, survive eviction).
    ``cache_dir`` enables on-disk persistence. ``autotune=True`` replaces
    the cost model's backend choice with a measured winner on first build;
    ``autotune_backends`` restricts the probed candidates and
    ``probe_iters`` sets the timed repetitions per candidate.
    """

    def __init__(self, capacity: int = 64, cache_dir: Optional[str] = None,
                 autotune: bool = False,
                 autotune_backends: Optional[Tuple[str, ...]] = None,
                 probe_iters: int = 3):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.cache_dir = cache_dir
        self.autotune = autotune
        self.autotune_backends = tuple(autotune_backends or BACKENDS)
        self.probe_iters = probe_iters
        self._lock = threading.RLock()
        self._entries: "OrderedDict[str, SpgemmStructure]" = OrderedDict()
        self._stats: Dict[str, int] = dict(hits=0, misses=0, evictions=0,
                                           disk_hits=0, autotuned=0)
        if cache_dir is not None:
            os.makedirs(cache_dir, exist_ok=True)

    # ------------------------------------------------------------- lookup

    def get(self, a: EllRows, b: EllCols, **make_kwargs) -> SpgemmStructure:
        """The structure for ``(a, b)``'s sparsity pattern — from memory,
        then disk, then a fresh symbolic-phase build (optionally autotuned).
        ``make_kwargs`` forward to ``make_structure`` on a build (``out_cap``,
        ``backend``, ``n_dev``, ``schedules``, ...); they do not affect the
        cache key, so callers sharing a cache should agree on them."""
        fp = fingerprint(a, b)
        with self._lock:
            st = self._entries.get(fp)
            if st is not None:
                self._entries.move_to_end(fp)
                self._stats["hits"] += 1
                hit = True
            else:
                hit = False
        if hit:
            _obs_metrics.inc("structure_cache.hits")
            return st
        if self.cache_dir is not None:
            st = self._load_disk(fp)
            if st is not None:
                with self._lock:
                    self._stats["disk_hits"] += 1
                _obs_metrics.inc("structure_cache.disk_hits")
                self._insert(fp, st, write_disk=False)
                return st
        with self._lock:
            self._stats["misses"] += 1
        _obs_metrics.inc("structure_cache.misses")
        if self.autotune:
            make_kwargs = dict(make_kwargs)
            make_kwargs["plan"] = self._autotune_plan(a, b, make_kwargs)
        with _obs.span("structure_cache.build", fp=fp[:12]):
            st = make_structure(a, b, **make_kwargs)
        self._insert(fp, st, write_disk=True)
        return st

    def stats(self) -> Dict[str, int]:
        """Counters snapshot: hits, misses, evictions, disk_hits, autotuned,
        plus the current ``size``. Cheap under contention: only the raw
        counter reads happen under the LRU lock; the returned dict is built
        outside it."""
        with self._lock:
            items = tuple(self._stats.items())
            size = len(self._entries)
        out = dict(items)
        out["size"] = size
        return out

    def clear(self) -> None:
        """Drop every in-memory entry (disk copies are kept) and zero the
        counters."""
        with self._lock:
            self._entries.clear()
            for k in self._stats:
                self._stats[k] = 0

    # ------------------------------------------------------------ internals

    def _insert(self, fp: str, st: SpgemmStructure, *,
                write_disk: bool) -> None:
        with self._lock:
            self._entries[fp] = st
            self._entries.move_to_end(fp)
            evicted = 0
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._stats["evictions"] += 1
                evicted += 1
        if evicted:
            _obs_metrics.inc("structure_cache.evictions", evicted)
        if write_disk and self.cache_dir is not None:
            self._save_disk(fp, st)

    def _autotune_plan(self, a: EllRows, b: EllCols,
                       make_kwargs: dict) -> Plan:
        """Short timed probes of each candidate backend on the real
        operands; the measured winner's plan is returned with per-backend
        timings recorded in ``est['autotune_us']``."""
        from repro.core.spgemm import spgemm_coo
        from .planner import make_plan
        kw = dict(out_cap=make_kwargs.get("out_cap"),
                  tile=make_kwargs.get("tile", 4096),
                  slack=make_kwargs.get("slack", 1.0))
        if kw["tile"] is None:
            kw["tile"] = 4096
        times: Dict[str, float] = {}
        plans: Dict[str, Plan] = {}
        for bk in self.autotune_backends:
            try:
                p = make_plan(a, b, backend=bk, **kw)
                run = lambda: jax.block_until_ready(
                    spgemm_coo(a, b, plan=p).val)
                run()  # compile + warm
                t0 = time.perf_counter()
                for _ in range(self.probe_iters):
                    run()
                times[bk] = (time.perf_counter() - t0) / self.probe_iters
                plans[bk] = p
            except Exception:  # backend inapplicable here → not a candidate
                continue
        if not times:
            return make_plan(a, b, **kw)
        winner = min(times, key=times.get)
        with self._lock:
            self._stats["autotuned"] += 1
        _obs_metrics.inc("structure_cache.autotuned")
        est = dict(plans[winner].est)
        est["autotune_us"] = {k: v * 1e6 for k, v in times.items()}
        return dataclasses.replace(plans[winner], est=est)

    # ----------------------------------------------------------------- disk

    def _path(self, fp: str) -> str:
        return os.path.join(self.cache_dir, f"{fp}.npz")

    def _save_disk(self, fp: str, st: SpgemmStructure) -> None:
        meta = dict(version=_FORMAT_VERSION, n_rows=st.n_rows,
                    n_cols=st.n_cols, out_cap=st.out_cap, fp=st.fp,
                    plan=_plan_to_dict(st.plan),
                    dist_plans=[[s, _dist_plan_to_dict(dp)]
                                for s, dp in st.dist_plans])
        path = self._path(fp)
        tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, key=np.asarray(jax.device_get(st.key)),
                         row_nnz=np.asarray(jax.device_get(st.row_nnz)),
                         seg=np.asarray(jax.device_get(st.seg)),
                         nnz=np.asarray(jax.device_get(st.nnz)),
                         meta=np.frombuffer(json.dumps(meta).encode(),
                                            dtype=np.uint8))
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def _load_disk(self, fp: str) -> Optional[SpgemmStructure]:
        path = self._path(fp)
        if not os.path.exists(path):
            return None
        try:
            with np.load(path) as z:
                meta = json.loads(bytes(z["meta"]).decode())
                if meta.get("version") != _FORMAT_VERSION \
                        or meta.get("fp") != fp:
                    return None
                import jax.numpy as jnp
                return SpgemmStructure(
                    key=jnp.asarray(z["key"]),
                    row_nnz=jnp.asarray(z["row_nnz"]),
                    seg=jnp.asarray(z["seg"]),
                    nnz=jnp.asarray(z["nnz"]),
                    n_rows=meta["n_rows"], n_cols=meta["n_cols"],
                    out_cap=meta["out_cap"], fp=meta["fp"],
                    plan=_plan_from_dict(meta["plan"]),
                    dist_plans=tuple(
                        (s, _dist_plan_from_dict(d))
                        for s, d in meta.get("dist_plans", [])))
        except Exception:  # corrupt / partial / foreign file → plain miss
            return None
