"""Symbolic phase, reified: the output *structure* of C = A·B as a value.

Classic two-phase SpGEMM (Deveci et al. 2018; Nagasaka et al. 2018) splits
the multiply into a **symbolic** pass — which output coordinates exist, how
many per row — and a **numeric** pass that only computes values. Production
sparse workloads (GNN layers, iterative graph algorithms, repeated sparse
layer applies at serve time) multiply the *same sparsity pattern* thousands
of times, so the symbolic result is worth keeping: this module computes it
once and packages it as an immutable :class:`SpgemmStructure` pytree that
``core.spgemm.spgemm_coo_numeric`` consumes to skip planning and coordinate
sorting entirely on every repeat call.

A structure is keyed by a cheap sparsity **fingerprint** — a hash of the
ELLPACK *index* planes plus shapes and value dtype, values excluded — so a
value-only change (new weights, new iteration of a fixed-pattern solver)
reuses the cached structure while any pattern change misses.  The companion
cache layer lives in ``plan.cache``.

Contents of a structure:

  * ``key``      — the sorted unique packed output coordinates of C
                   (``row·n_cols + col``), padded to ``out_cap`` with
                   ``KEY_INVALID``: the numeric phase maps every product to
                   its output slot by one ``searchsorted`` against this.
  * ``row_nnz``  — per-row unique-coordinate counts of C.
  * ``seg``      — row segment boundaries (exclusive prefix sum of
                   ``row_nnz``), CSR-style ``indptr`` of the output.
  * ``nnz``      — the true unique count (becomes ``Coo.ngroups``).
  * ``plan``     — the single-device :class:`~repro.plan.planner.Plan`.
  * ``dist_plans`` — optional per-schedule
                   :class:`~repro.plan.planner.DistPlan` entries (built when
                   ``make_structure(..., n_dev=...)`` is given; any of
                   ``'ring' | 'cstat' | 'summa'`` via ``schedules=``), so the
                   distributed path reuses planning per schedule too — the
                   warm numeric path also reads the cached pick (and its
                   ``pr × pc`` grid) to choose its rotation schedule.

Packed int32 keys require ``n_rows·n_cols < 2³¹`` — the same structural
precondition every packed-key backend carries; larger coordinate spaces stay
on the cold unpacked two-key ``'sort'`` path (``spgemm_coo`` routes there
automatically).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import EllCols, EllRows
from repro.kernels.bitonic_merge import KEY_INVALID
from repro.obs import trace as _obs

from . import symbolic
from .planner import DistPlan, Plan, SCHEDULES, make_dist_plan, make_plan


def fingerprint(a: EllRows, b: EllCols) -> str:
    """Sparsity fingerprint of an operand pair: a hash over the ELLPACK
    *index* planes, logical shapes and value dtypes — values excluded.

    Two operand pairs share a fingerprint iff they have identical sparsity
    patterns (same coordinates in the same slots) and value dtypes, which is
    exactly the condition under which a cached :class:`SpgemmStructure` (and
    any :class:`Plan`) transfers losslessly. Requires concrete operands —
    jit/vmap tracers carry no index bytes to hash.
    """
    if isinstance(a.val, jax.core.Tracer) or isinstance(b.val, jax.core.Tracer):
        raise ValueError(
            "fingerprint needs concrete operands; under jit/vmap the index "
            "planes are abstract — fingerprint outside the trace (where the "
            "structure/plan is built) and close over the result")
    h = hashlib.sha1()
    for idx, logical in ((a.idx, a.n_rows), (b.idx, b.n_cols)):
        arr = np.ascontiguousarray(np.asarray(jax.device_get(idx)))
        h.update(repr((arr.shape, int(logical), arr.dtype.str)).encode())
        h.update(arr.tobytes())
    h.update(repr((np.dtype(a.val.dtype).str,
                   np.dtype(b.val.dtype).str)).encode())
    return h.hexdigest()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SpgemmStructure:
    """Immutable symbolic-phase result of C = A·B (see module docstring).

    A registered pytree: the coordinate arrays are leaves (so a structure
    can be passed straight through ``jit``/``vmap`` boundaries), everything
    else — shapes, caps, fingerprint, plans — is static aux data, hashable
    so jitted numeric functions taking a structure argument cache compiles
    per pattern. Batched structures (from ``make_structure_batched``) carry
    a leading batch axis on every leaf, including ``nnz``.
    """

    key: jax.Array       # (out_cap,) int32 sorted unique packed coords
    row_nnz: jax.Array   # (n_rows,) int32 per-row unique counts
    seg: jax.Array       # (n_rows + 1,) int32 row segment boundaries
    nnz: jax.Array       # () int32 true unique count (→ Coo.ngroups)
    n_rows: int
    n_cols: int
    out_cap: int
    fp: Optional[str]
    plan: Plan
    dist_plans: Tuple[Tuple[str, DistPlan], ...] = ()

    def tree_flatten(self):
        return ((self.key, self.row_nnz, self.seg, self.nnz),
                (self.n_rows, self.n_cols, self.out_cap, self.fp,
                 self.plan, self.dist_plans))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def batched(self) -> bool:
        return self.key.ndim == 2

    def dist_plan(self, schedule: Optional[str] = None) -> DistPlan:
        """The cached :class:`DistPlan` for ``schedule`` (or the only one /
        the planner's pick when ``None``). Raises with a rebuild hint when
        the structure was made without ``n_dev``."""
        if not self.dist_plans:
            raise ValueError(
                "structure holds no distributed plans — rebuild with "
                "make_structure(..., n_dev=mesh.shape[axis]) (optionally "
                "schedules=('ring', 'cstat', 'summa')) to cache them")
        plans = dict(self.dist_plans)
        if schedule is None:
            return plans[self.dist_plans[0][0]]
        if schedule not in plans:
            raise ValueError(
                f"structure caches no {schedule!r} DistPlan (has "
                f"{tuple(plans)}); rebuild with make_structure(..., "
                f"schedules=({schedule!r},))")
        return plans[schedule]

    def validate(self, a: EllRows, b: EllCols) -> None:
        """Raise ``ValueError`` when ``(a, b)``'s sparsity fingerprint does
        not match the one this structure was built for (silent reuse of a
        stale structure would scatter values into the wrong coordinates).
        Tracer operands skip the content hash — cheap shape checks still
        apply."""
        if a.n_rows != self.n_rows or b.n_cols != self.n_cols:
            raise ValueError(
                f"structure built for a {self.n_rows}x{self.n_cols} output "
                f"but operands produce {a.n_rows}x{b.n_cols}")
        if (self.fp is not None
                and not isinstance(a.val, jax.core.Tracer)
                and not isinstance(b.val, jax.core.Tracer)):
            got = fingerprint(a, b)
            if got != self.fp:
                raise ValueError(
                    "stale structure: operands' sparsity fingerprint "
                    f"{got[:12]}… differs from the structure's "
                    f"{self.fp[:12]}… — the sparsity pattern changed, so "
                    "cached output coordinates no longer apply. Rebuild "
                    "with make_structure (or fetch through "
                    "plan.cache.StructureCache, which keys on the "
                    "fingerprint and re-derives automatically)")


def _check_packable(n_rows: int, n_cols: int) -> None:
    if n_rows * n_cols >= jnp.iinfo(jnp.int32).max:
        raise ValueError(
            f"coordinate space {n_rows}x{n_cols} exceeds packed int32 keys; "
            "the structure/numeric fast path cannot span it — use the cold "
            "spgemm_coo path (its unpacked two-key 'sort' route handles "
            "such spaces automatically)")


@functools.partial(jax.jit, static_argnames=("n_rows", "n_cols", "out_cap"))
def _structure_arrays(a_idx: jax.Array, b_idx: jax.Array, *, n_rows: int,
                      n_cols: int, out_cap: int):
    """Coordinate-only symbolic pass → (key, row_nnz, seg, nnz).

    One packed-key sort of the broadcast coordinate planes (no value
    multiply, no value sort — the same pass ``symbolic.exact_nnz_rows``
    runs, extended to *keep* the sorted unique keys), then a cumsum scatter
    compacts the run heads into ``out_cap`` slots.
    """
    k_a, n = a_idx.shape
    k_b = b_idx.shape[1]
    row = jnp.broadcast_to(a_idx[:, :, None], (k_a, n, k_b)).reshape(-1)
    col = jnp.broadcast_to(b_idx[None, :, :], (k_a, n, k_b)).reshape(-1)
    ok = jnp.logical_and(row >= 0, col >= 0)
    key = jnp.where(ok, row * n_cols + col, KEY_INVALID).astype(jnp.int32)
    key = jax.lax.sort(key, dimension=0, is_stable=False)
    head = (key != jnp.roll(key, 1)).at[0].set(True)
    head = jnp.logical_and(head, key != KEY_INVALID)
    nnz = jnp.sum(head).astype(jnp.int32)
    dst = jnp.minimum(jnp.where(head, jnp.cumsum(head) - 1, out_cap), out_cap)
    uniq = (jnp.full((out_cap + 1,), KEY_INVALID, jnp.int32)
            .at[dst].set(jnp.where(head, key, KEY_INVALID)))[:out_cap]
    rid = jnp.where(head, key // n_cols, n_rows)
    row_nnz = jax.ops.segment_sum(head.astype(jnp.int32),
                                  jnp.minimum(rid, n_rows),
                                  num_segments=n_rows + 1)[:n_rows]
    seg = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                           jnp.cumsum(row_nnz).astype(jnp.int32)])
    return uniq, row_nnz, seg, nnz


def make_structure(a: EllRows, b: EllCols, *, out_cap: Optional[int] = None,
                   backend: Optional[str] = None, tile: int = 4096,
                   slack: float = 1.0, n_dev: Optional[int] = None,
                   schedules: Optional[Tuple[str, ...]] = None,
                   plan: Optional[Plan] = None) -> SpgemmStructure:
    """Run the symbolic phase once on concrete operands → ``SpgemmStructure``.

    Computes C's sorted unique output coordinates, per-row nnz and segment
    boundaries, plus a :class:`Plan` (``plan=`` supplies a prebuilt one,
    e.g. an autotuned winner; otherwise ``make_plan`` runs with the given
    ``out_cap``/``backend``/``tile``/``slack``). With ``n_dev`` set, a
    :class:`DistPlan` is additionally built and cached per entry of
    ``schedules`` (default: the planner's preferred schedule only), so
    distributed repeat calls skip ``make_dist_plan`` too.

    The result is keyed by ``fingerprint(a, b)`` and is valid for any
    operand pair with the identical sparsity pattern regardless of values.
    """
    _check_packable(a.n_rows, b.n_cols)
    fp = fingerprint(a, b)
    if plan is None:
        plan = make_plan(a, b, out_cap=out_cap, backend=backend, tile=tile,
                         slack=slack)
    out_cap = plan.out_cap
    with _obs.span("structure.build", fp=fp[:12], out_cap=out_cap,
                   backend=plan.backend):
        key, row_nnz, seg, nnz = _structure_arrays(
            a.idx, b.idx, n_rows=a.n_rows, n_cols=b.n_cols, out_cap=out_cap)
        _obs.sync(key)
    if int(jax.device_get(nnz)) > out_cap:
        raise ValueError(
            f"out_cap={out_cap} smaller than nnz(C)={int(jax.device_get(nnz))}"
            " — a structure must hold every output coordinate (pass a larger"
            " out_cap or let make_plan size it)")
    dist_plans: Tuple[Tuple[str, DistPlan], ...] = ()
    if n_dev is not None:
        if schedules is None:
            dp = make_dist_plan(a, b, n_dev=n_dev, out_cap=out_cap,
                                backend=plan.backend, tile=tile, slack=slack)
            dist_plans = ((dp.schedule, dp),)
        else:
            for s in schedules:
                if s not in SCHEDULES:
                    raise ValueError(
                        f"unknown schedule {s!r}; expected {SCHEDULES}")
            dist_plans = tuple(
                (s, make_dist_plan(a, b, n_dev=n_dev, schedule=s,
                                   out_cap=out_cap, backend=plan.backend,
                                   tile=tile, slack=slack))
                for s in schedules)
    return SpgemmStructure(key=key, row_nnz=row_nnz, seg=seg, nnz=nnz,
                           n_rows=a.n_rows, n_cols=b.n_cols, out_cap=out_cap,
                           fp=fp, plan=plan, dist_plans=dist_plans)


def make_structure_batched(a: EllRows, b: EllCols, *,
                           out_cap: Optional[int] = None,
                           backend: Optional[str] = None, tile: int = 4096,
                           slack: float = 1.0) -> SpgemmStructure:
    """Per-batch-element symbolic phase over a leading batch axis.

    Every element gets its own sorted-key plane (patterns may differ across
    the batch); ``out_cap`` and the plan are shared — sized on the widest
    element so no element overflows. Leaves carry the batch axis first,
    matching ``spgemm_coo_batched``'s ``Coo`` layout; consume with
    ``spgemm_coo_numeric_batched``.
    """
    if a.val.ndim != 3 or b.val.ndim != 3:
        raise ValueError("batched operands need a leading batch axis on all "
                         f"ELLPACK planes; got A {a.val.ndim}D, "
                         f"B {b.val.ndim}D")
    _check_packable(a.n_rows, b.n_cols)
    bsz = a.val.shape[0]
    slices_a = [EllRows(a.val[i], a.idx[i], a.n_rows) for i in range(bsz)]
    slices_b = [EllCols(b.val[i], b.idx[i], b.n_cols) for i in range(bsz)]
    fp = fingerprint(a, b)
    if out_cap is None:
        caps = [symbolic.out_cap_auto(ai, bi, slack=slack)
                for ai, bi in zip(slices_a, slices_b)]
        out_cap = max(caps)
    plan = make_plan(slices_a[0], slices_b[0], out_cap=out_cap,
                     backend=backend, tile=tile, slack=slack)
    parts = [_structure_arrays(ai.idx, bi.idx, n_rows=a.n_rows,
                               n_cols=b.n_cols, out_cap=out_cap)
             for ai, bi in zip(slices_a, slices_b)]
    key, row_nnz, seg, nnz = (jnp.stack([p[i] for p in parts])
                              for i in range(4))
    if int(jax.device_get(nnz).max()) > out_cap:
        raise ValueError(
            f"out_cap={out_cap} smaller than the widest batch element's "
            f"nnz(C)={int(jax.device_get(nnz).max())}")
    return SpgemmStructure(key=key, row_nnz=row_nnz, seg=seg, nnz=nnz,
                           n_rows=a.n_rows, n_cols=b.n_cols, out_cap=out_cap,
                           fp=fp, plan=plan)
