"""Symbolic phase: size nnz(C) before running the numeric SpGEMM.

Classic CSR SpGEMM does a "symbolic" pass so the output can be allocated
exactly; SPLIM's static-shape JAX realization needs the same thing for a
different reason — ``out_cap`` is a *trace-time* constant, so guessing it
small truncates (detectable via ``Coo.ngroups`` but still lost work) and
guessing it large wastes memory and sort width. This module derives it:

  * ``product_count``  — Σ_c nnzcol_A(c)·nnzrow_B(c), the exact number of
    scalar products SCCP performs (the paper's NK² term; alias of
    ``sccp.count_products``).
  * ``upper_bound_nnz`` — row-flop counting over the ELL planes: output row r
    receives at most Σ_{lanes of A with idx==r} nnzrow_B(c) products, and at
    most n_cols distinct coordinates. One segment-sum, no product stream.
  * ``exact_nnz``      — the exact unique-coordinate count, reusing the sort
    infrastructure on *coordinates only* (no value multiply, no value sort):
    lexicographic (row, col) sort of the broadcast coordinate planes, then a
    run-head count. Costs one stream sort — worth it when the numeric pass
    will be re-run (iterative workloads) or when the bound is loose.

All three are jittable and return traced int32 scalars. ``out_cap_auto`` is
the host-side planning entry: concrete operands in, Python int out (rounded
up to a lane multiple so downstream scatters stay aligned).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import EllCols, EllRows
from repro.core.sccp import count_products, count_products_rows

LANE = 128   # round out_cap to full VPU lanes


def product_count(a: EllRows, b: EllCols) -> jax.Array:
    """Exact count of valid SCCP products (= upper bound on duplicates+uniques)."""
    return count_products(a, b)


def product_count_rows(a: EllRows, b: EllCols) -> jax.Array:
    """Per-output-row SCCP product counts (alias of sccp.count_products_rows)."""
    return count_products_rows(a, b)


def upper_bound_nnz(a: EllRows, b: EllCols) -> jax.Array:
    """Upper bound on nnz(C): per-row flops clipped to the row width."""
    return jnp.minimum(product_count_rows(a, b),
                       b.n_cols).sum().astype(jnp.int32)


def exact_nnz_rows(a: EllRows, b: EllCols) -> jax.Array:
    """Per-row exact unique-coordinate counts of C (coordinate-only pass).

    Reuses the sort infrastructure on coordinates only — no value multiply,
    no value sort: lexicographic (row, col) sort of the broadcast coordinate
    planes, then run heads counted per row.
    """
    row = jnp.broadcast_to(a.idx[:, :, None],
                           (a.k, a.n_cols, b.k)).reshape(-1)
    col = jnp.broadcast_to(b.idx[None, :, :],
                           (a.k, b.n_rows, b.k)).reshape(-1)
    ok = jnp.logical_and(row >= 0, col >= 0)
    row_s = jnp.where(ok, row, a.n_rows)                        # park invalid last
    col_s = jnp.where(ok, col, 0)
    row_s, col_s = jax.lax.sort((row_s, col_s), dimension=0, num_keys=2,
                                is_stable=False)
    head = jnp.logical_or(row_s != jnp.roll(row_s, 1),
                          col_s != jnp.roll(col_s, 1)).at[0].set(True)
    head = jnp.logical_and(head, row_s < a.n_rows)
    return jax.ops.segment_sum(head.astype(jnp.int32),
                               jnp.minimum(row_s, a.n_rows),
                               num_segments=a.n_rows + 1)[: a.n_rows]


def exact_nnz(a: EllRows, b: EllCols) -> jax.Array:
    """Exact nnz(C): coordinate-only symbolic pass (one sort, no values)."""
    return exact_nnz_rows(a, b).sum().astype(jnp.int32)


def per_slab_products(a: EllRows, b: EllCols) -> jax.Array:
    """Per-A-slab SCCP product counts: ``out[i]`` = products contributed by
    A slab ``i`` (= Σ_c valid(a.idx[i,c])·nnzrow_B(c)).

    Slab ``i`` of A is exactly what lives on one device under the
    B-stationary ring's ``P(axis, None)`` sharding, so contiguous-block sums
    of this vector are the *exact* per-device product-stream sizes — the
    distributed planner's ``local_cap`` input (``per_shard_products``).
    """
    b_row_nnz = b.valid_mask().sum(axis=1)                     # (n,)
    w = jnp.where(a.idx >= 0, b_row_nnz[None, :], 0)           # (k_a, n)
    return w.sum(axis=1).astype(jnp.int32)


def max_slab_products(a: EllRows, b: EllCols) -> jax.Array:
    """Largest single-slab product count — the streaming engine's per-tile
    compaction bound (``Plan.stream_cap``): one A slab contributes at most
    this many valid products, and a tile's unique coordinates never exceed
    its products, so a compaction width of this bound never drops."""
    return per_slab_products(a, b).max()


def per_shard_products(a: EllRows, b: EllCols, n_shards: int) -> jax.Array:
    """Exact product-stream size per contiguous A-slab shard.

    Pads ``k_a`` up to a multiple of ``n_shards`` (padding slabs contribute
    zero products — they are all-INVALID lanes, matching the slab padding
    the distributed engine applies) and sums slab counts per shard.
    """
    per_slab = per_slab_products(a, b)
    k = per_slab.shape[0]
    pad = (-k) % n_shards
    per_slab = jnp.concatenate(
        [per_slab, jnp.zeros((pad,), per_slab.dtype)]) if pad else per_slab
    return per_slab.reshape(n_shards, -1).sum(axis=1)


def per_grid_products(a: EllRows, b: EllCols, pr: int, pc: int) -> jax.Array:
    """Exact SCCP product counts per logical 2D-grid cell — ``(pr, pc)``.

    The 2D (SUMMA-style) distributed schedule factors ``p = pr·pc`` devices
    into a grid; device ``(r, c)`` multiplies the A slabs held by its grid
    *row* (A shard-blocks ``[r·pc, (r+1)·pc)``, a contiguous slab range)
    against the B slabs held by its grid *column* (B shard-blocks
    ``{r'·pc + c}``, stride-``pc``). ``out[r, c]`` is the exact number of
    valid products that cell computes — the 2D analogue of
    ``per_shard_products``, and the distributed planner's ``local_cap``
    input for ``schedule='summa'`` (cells partition the product stream, so
    caps sized from this histogram never drop).

    Slab axes are padded up to a multiple of ``p`` exactly like the engine's
    ``pad_slabs_{a,b}`` (padding lanes are all-INVALID → zero products).
    ``per_grid_products(a, b, p, 1)[:, 0] == per_shard_products(a, b, p)``.
    """
    p = pr * pc
    a_valid = (a.idx >= 0).astype(jnp.int32)                   # (k_a, n)
    b_valid = b.valid_mask().astype(jnp.int32)                 # (n, k_b)
    pad_a = (-a_valid.shape[0]) % p
    if pad_a:
        a_valid = jnp.concatenate(
            [a_valid, jnp.zeros((pad_a, a_valid.shape[1]), jnp.int32)])
    pad_b = (-b_valid.shape[1]) % p
    if pad_b:
        b_valid = jnp.concatenate(
            [b_valid, jnp.zeros((b_valid.shape[0], pad_b), jnp.int32)], axis=1)
    n = a_valid.shape[1]
    # per-(shard-block, inner-pos) valid-lane counts on both sides
    blk_a = a_valid.reshape(p, -1, n).sum(axis=1)              # (p, n)
    blk_b = b_valid.reshape(n, p, -1).sum(axis=2).T            # (p, n)
    g = blk_a @ blk_b.T                                        # (p, p) exact
    # row panel r = A blocks [r·pc, (r+1)·pc); col panel c = B blocks r'·pc+c
    return (g.reshape(pr, pc, pr, pc).sum(axis=(1, 2))
            .astype(jnp.int32))


def per_block_nnz(a: EllRows, b: EllCols, n_blocks: int, *,
                  exact: bool = True) -> jax.Array:
    """Per-row-block unique-coordinate counts of C (``n_blocks`` contiguous
    blocks of ``ceil(n_rows/n_blocks)`` rows — the C-stationary ownership
    partition). ``exact=False`` substitutes the clipped row-flop bound,
    which dominates the true uniques, so block caps sized from it stay safe.
    """
    per_row = (exact_nnz_rows(a, b) if exact
               else jnp.minimum(product_count_rows(a, b),
                                b.n_cols).astype(jnp.int32))
    rpb = -(-a.n_rows // n_blocks)
    pad = n_blocks * rpb - a.n_rows
    per_row = jnp.concatenate(
        [per_row, jnp.zeros((pad,), per_row.dtype)]) if pad else per_row
    return per_row.reshape(n_blocks, rpb).sum(axis=1)


def per_row_counts(a: EllRows, b: EllCols, *, exact: bool = True):
    """(products_per_row, unique_per_row) — the planner's histogram inputs.

    ``exact=False`` substitutes the clipped row-flop bound for the unique
    counts; bucket/table sizing stays safe because the bound dominates the
    true per-row uniques.
    """
    prod = product_count_rows(a, b)
    uniq = (exact_nnz_rows(a, b) if exact
            else jnp.minimum(prod, b.n_cols).astype(jnp.int32))
    return prod, uniq


def out_cap_auto(a: EllRows, b: EllCols, *, exact: bool = True,
                 slack: float = 1.0) -> int:
    """Host-side ``out_cap`` derivation from concrete operands.

    ``exact=True`` runs the coordinate-only sort pass (tight); ``False``
    uses the row-flop upper bound (cheap, possibly loose). ``slack`` > 1
    leaves headroom for reuse of the plan across similarly-sparse inputs.
    Always a multiple of LANE and at least LANE.
    """
    nnz = int(exact_nnz(a, b) if exact else upper_bound_nnz(a, b))
    want = int(-(-int(nnz * slack) // LANE)) * LANE
    return max(LANE, want)
