"""Adaptive SpGEMM planning: symbolic sizing + accumulation-backend choice.

The layer between formats and kernels: ``make_plan`` inspects concrete
ELLPACK operands (symbolic nnz(C) pass, product/unique histograms, the
hwmodel cost model) and returns a static ``Plan`` that ``core.spgemm_coo``
dispatches on — ``spgemm_coo(a, b, out_cap='auto', accumulator='auto')``
is the one-call form.

  symbolic  — upper-bound and exact nnz(C) estimators (out_cap derivation)
              plus per-shard product / per-row-block nnz histograms
  planner   — MatrixStats-driven choice among sort | tiled | bucket | hash
              | stream (memory-aware: the streaming engine wins when the
              materialized product stream exceeds the byte budget) plus
              tile/bucket/table/stream sizing; ``make_dist_plan`` extends the
              plan across a mesh axis (schedule choice + exchange sizing for
              ``core.distributed.spgemm_coo_sharded``)
  structure — the symbolic phase reified: ``make_structure`` computes C's
              output coordinates once as an immutable, fingerprint-keyed
              ``SpgemmStructure`` that ``core.spgemm_coo_numeric`` consumes
              to skip planning and coordinate sorting on repeat calls
  cache     — ``StructureCache``: fingerprint-keyed LRU over structures with
              optional on-disk persistence and measured autotune
"""
from . import cache, planner, structure, symbolic
from .cache import StructureCache
from .planner import (BACKENDS, SCHEDULES, DistPlan, Plan, make_dist_plan,
                      make_plan, plan_spmm_format)
from .structure import (SpgemmStructure, fingerprint, make_structure,
                        make_structure_batched)
from .symbolic import (exact_nnz, out_cap_auto, per_block_nnz,
                       per_shard_products, upper_bound_nnz)

__all__ = ["BACKENDS", "SCHEDULES", "DistPlan", "Plan", "SpgemmStructure",
           "StructureCache", "cache", "exact_nnz", "fingerprint",
           "make_dist_plan", "make_plan", "make_structure",
           "make_structure_batched", "out_cap_auto", "per_block_nnz",
           "per_shard_products", "plan_spmm_format", "planner", "structure",
           "symbolic", "upper_bound_nnz"]
