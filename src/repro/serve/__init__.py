from .engine import (ServeConfig, ServingEngine, SparseGemmBatcher,
                     SparseGemmRequest)

__all__ = ["ServeConfig", "ServingEngine", "SparseGemmBatcher",
           "SparseGemmRequest"]
