"""Batched serving engine: static-batch continuous decoding.

Requests join a queue; the engine packs up to ``max_batch`` of them into a
fixed-shape slot array (static shapes keep one compiled prefill + one
compiled decode program alive), runs prefill per admission, then shared
decode steps. Finished slots (EOS or max tokens) are recycled for queued
requests — continuous batching on a static grid.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_prompt: int = 64
    max_new_tokens: int = 32
    s_max: int = 128
    eos_id: int = 2
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # engine-level SpGEMM structure cache (plan.cache.StructureCache): one
    # symbolic phase per sparsity pattern across ALL requests; on-disk
    # persistence warm-starts restarted replicas; autotune replaces the cost
    # model's backend pick with a measured winner on first use.
    structure_cache_size: int = 64
    structure_cache_dir: Optional[str] = None
    structure_autotune: bool = False


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enq: float = 0.0          # wall-clock at admission
    t_done: float = 0.0         # wall-clock at completion


class EngineStats(dict):
    """Engine counters: a plain dict (``eng.stats["tokens"]`` keeps working)
    that is also callable — ``eng.stats()`` returns a full snapshot joining
    the counters with per-request latency aggregates, mean batch occupancy,
    and the structure cache's own counters."""

    def __init__(self, engine: "ServingEngine"):
        super().__init__(requests=0, tokens=0, decode_s=0.0, prefill_s=0.0,
                         queue_s=0.0, compute_s=0.0, decode_steps=0,
                         occupancy_sum=0.0)
        self._engine = engine

    def __call__(self) -> Dict:
        snap = {k: v for k, v in self.items()}
        steps = snap.pop("decode_steps")
        occ = snap.pop("occupancy_sum")
        n = max(1, snap["requests"])
        snap["decode_steps"] = steps
        snap["batch_occupancy"] = occ / steps if steps else 0.0
        snap["queue_s_per_request"] = snap["queue_s"] / n
        snap["compute_s_per_request"] = snap["compute_s"] / n
        snap["structure_cache"] = self._engine.structure_cache.stats()
        return snap


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.s_max))
        self._rng = np.random.default_rng(cfg.seed)
        from repro.plan.cache import StructureCache
        self.structure_cache = StructureCache(
            capacity=cfg.structure_cache_size,
            cache_dir=cfg.structure_cache_dir,
            autotune=cfg.structure_autotune)
        self.stats = EngineStats(self)

    def spgemm(self, a, b, **structure_kwargs):
        """Two-phase SpGEMM through the engine's shared structure cache.

        Any sparse multiply issued on behalf of a request (sparse-FFN
        applies, GNN-style feature propagation) lands here: the first
        request with a given sparsity pattern pays the symbolic phase, every
        subsequent request — across the whole engine lifetime, and across
        restarts when ``structure_cache_dir`` is set — runs numeric-only.
        ``structure_kwargs`` forward to the structure build on a miss."""
        from repro.core.spgemm import spgemm_coo_numeric
        structure = self.structure_cache.get(a, b, **structure_kwargs)
        # the cache key already proved the fingerprint matches
        return spgemm_coo_numeric(a, b, structure, validate=False)

    def cache_stats(self):
        """Structure-cache counters (hits/misses/evictions/disk_hits/size)
        alongside the serving counters in ``self.stats``."""
        return self.structure_cache.stats()

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.cfg.greedy:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / max(self.cfg.temperature, 1e-3)
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(q), p=q) for q in p],
                        dtype=np.int32)

    def generate_batch(self, prompts: List[np.ndarray]) -> List[List[int]]:
        """Serve one admission wave of ≤ max_batch prompts to completion."""
        cfg = self.cfg
        assert len(prompts) <= cfg.max_batch
        b = len(prompts)
        t_enq = time.time()
        reqs = [Request(i, p, t_enq=t_enq) for i, p in enumerate(prompts)]
        plen = max(len(p) for p in prompts)
        toks = np.full((b, plen), cfg.eos_id, np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p      # left-pad so last pos = last token
        t0 = time.time()
        # admission → prefill-start is this engine's queue phase
        self.stats["queue_s"] += (t0 - t_enq) * b
        _obs_metrics.observe("serve.queue_us", (t0 - t_enq) * 1e6)
        with _obs.span("serve.prefill", batch=b, prompt_len=plen):
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)})
            _obs.sync(logits)
        self.stats["prefill_s"] += time.time() - t0
        self.stats["requests"] += b
        # the first sampled token is a real emission: count it and honour EOS
        # so an immediately-finished request never enters the decode loop
        cur = self._sample(np.asarray(logits, np.float32))
        alive = False
        for r, t in zip(reqs, cur):
            r.out_tokens.append(int(t))
            self.stats["tokens"] += 1
            if t == cfg.eos_id:
                r.done = True
                r.t_done = time.time()
            else:
                alive = True
        t0 = time.time()
        steps = 0
        with _obs.span("serve.decode", batch=b) as _dsp:
            for _ in range(cfg.max_new_tokens - 1):
                if not alive:
                    break
                n_alive = sum(not r.done for r in reqs)
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur)[:, None])
                cur = self._sample(np.asarray(logits, np.float32))
                steps += 1
                # occupancy = live slots over the engine's static batch grid
                self.stats["occupancy_sum"] += n_alive / cfg.max_batch
                self.stats["decode_steps"] += 1
                _obs_metrics.gauge("serve.batch_occupancy",
                                   n_alive / cfg.max_batch)
                alive = False
                for r, t in zip(reqs, cur):
                    if r.done:
                        continue
                    r.out_tokens.append(int(t))
                    self.stats["tokens"] += 1
                    if t == cfg.eos_id:
                        r.done = True
                        r.t_done = time.time()
                    else:
                        alive = True
            _dsp.set(steps=steps)
        self.stats["decode_s"] += time.time() - t0
        t_end = time.time()
        for r in reqs:
            if not r.done:
                r.t_done = t_end
            compute_s = r.t_done - r.t_enq
            self.stats["compute_s"] += compute_s
            _obs_metrics.observe("serve.compute_us", compute_s * 1e6)
        return [r.out_tokens for r in reqs]
