"""Batched serving engine: static-batch continuous decoding.

Requests join a queue; the engine packs up to ``max_batch`` of them into a
fixed-shape slot array (static shapes keep one compiled prefill + one
compiled decode program alive), runs prefill per admission, then shared
decode steps. Finished slots (EOS or max tokens) are recycled for queued
requests — continuous batching on a static grid.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_prompt: int = 64
    max_new_tokens: int = 32
    s_max: int = 128
    eos_id: int = 2
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # engine-level SpGEMM structure cache (plan.cache.StructureCache): one
    # symbolic phase per sparsity pattern across ALL requests; on-disk
    # persistence warm-starts restarted replicas; autotune replaces the cost
    # model's backend pick with a measured winner on first use.
    structure_cache_size: int = 64
    structure_cache_dir: Optional[str] = None
    structure_autotune: bool = False


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.s_max))
        self._rng = np.random.default_rng(cfg.seed)
        from repro.plan.cache import StructureCache
        self.structure_cache = StructureCache(
            capacity=cfg.structure_cache_size,
            cache_dir=cfg.structure_cache_dir,
            autotune=cfg.structure_autotune)
        self.stats = {"requests": 0, "tokens": 0, "decode_s": 0.0,
                      "prefill_s": 0.0}

    def spgemm(self, a, b, **structure_kwargs):
        """Two-phase SpGEMM through the engine's shared structure cache.

        Any sparse multiply issued on behalf of a request (sparse-FFN
        applies, GNN-style feature propagation) lands here: the first
        request with a given sparsity pattern pays the symbolic phase, every
        subsequent request — across the whole engine lifetime, and across
        restarts when ``structure_cache_dir`` is set — runs numeric-only.
        ``structure_kwargs`` forward to the structure build on a miss."""
        from repro.core.spgemm import spgemm_coo_numeric
        structure = self.structure_cache.get(a, b, **structure_kwargs)
        # the cache key already proved the fingerprint matches
        return spgemm_coo_numeric(a, b, structure, validate=False)

    def cache_stats(self):
        """Structure-cache counters (hits/misses/evictions/disk_hits/size)
        alongside the serving counters in ``self.stats``."""
        return self.structure_cache.stats()

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.cfg.greedy:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / max(self.cfg.temperature, 1e-3)
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(q), p=q) for q in p],
                        dtype=np.int32)

    def generate_batch(self, prompts: List[np.ndarray]) -> List[List[int]]:
        """Serve one admission wave of ≤ max_batch prompts to completion."""
        cfg = self.cfg
        assert len(prompts) <= cfg.max_batch
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.full((b, plen), cfg.eos_id, np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p      # left-pad so last pos = last token
        t0 = time.time()
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.stats["prefill_s"] += time.time() - t0
        reqs = [Request(i, p) for i, p in enumerate(prompts)]
        self.stats["requests"] += b
        # the first sampled token is a real emission: count it and honour EOS
        # so an immediately-finished request never enters the decode loop
        cur = self._sample(np.asarray(logits, np.float32))
        alive = False
        for r, t in zip(reqs, cur):
            r.out_tokens.append(int(t))
            self.stats["tokens"] += 1
            if t == cfg.eos_id:
                r.done = True
            else:
                alive = True
        t0 = time.time()
        for _ in range(cfg.max_new_tokens - 1):
            if not alive:
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(cur)[:, None])
            cur = self._sample(np.asarray(logits, np.float32))
            alive = False
            for r, t in zip(reqs, cur):
                if r.done:
                    continue
                r.out_tokens.append(int(t))
                self.stats["tokens"] += 1
                if t == cfg.eos_id:
                    r.done = True
                else:
                    alive = True
        self.stats["decode_s"] += time.time() - t0
        return [r.out_tokens for r in reqs]
