"""Batched serving engine: static-batch continuous decoding.

Requests join a queue; the engine packs up to ``max_batch`` of them into a
fixed-shape slot array (static shapes keep one compiled prefill + one
compiled decode program alive), runs prefill per admission, then shared
decode steps. Finished slots (EOS or max tokens) are recycled for queued
requests — continuous batching on a static grid.

Sparse multiplies get the same treatment: :class:`SparseGemmBatcher` packs
heterogeneous per-request SpGEMMs that share shapes onto
``spgemm_coo_numeric_batched`` slots (structures recycled through the
engine-level ``StructureCache``; fingerprints may differ within one wave —
each slot carries its own key plane), reporting slot occupancy and
per-request latency through :class:`EngineStats`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    max_prompt: int = 64
    max_new_tokens: int = 32
    s_max: int = 128
    eos_id: int = 2
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    # engine-level SpGEMM structure cache (plan.cache.StructureCache): one
    # symbolic phase per sparsity pattern across ALL requests; on-disk
    # persistence warm-starts restarted replicas; autotune replaces the cost
    # model's backend pick with a measured winner on first use.
    structure_cache_size: int = 64
    structure_cache_dir: Optional[str] = None
    structure_autotune: bool = False


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enq: float = 0.0          # wall-clock at admission
    t_done: float = 0.0         # wall-clock at completion


class EngineStats(dict):
    """Engine counters: a plain dict (``eng.stats["tokens"]`` keeps working)
    that is also callable — ``eng.stats()`` returns a full snapshot joining
    the counters with per-request latency aggregates, mean batch occupancy
    (decode slots and SpGEMM slots), and the structure cache's own
    counters."""

    def __init__(self, engine: "ServingEngine"):
        super().__init__(requests=0, tokens=0, decode_s=0.0, prefill_s=0.0,
                         queue_s=0.0, compute_s=0.0, decode_steps=0,
                         occupancy_sum=0.0, spgemm_requests=0,
                         spgemm_waves=0, spgemm_batched_waves=0,
                         spgemm_occupancy_sum=0.0, spgemm_queue_s=0.0,
                         spgemm_compute_s=0.0)
        self._engine = engine

    def __call__(self) -> Dict:
        snap = {k: v for k, v in self.items()}
        steps = snap.pop("decode_steps")
        occ = snap.pop("occupancy_sum")
        n = max(1, snap["requests"])
        snap["decode_steps"] = steps
        snap["batch_occupancy"] = occ / steps if steps else 0.0
        snap["queue_s_per_request"] = snap["queue_s"] / n
        snap["compute_s_per_request"] = snap["compute_s"] / n
        bw = snap.get("spgemm_batched_waves", 0)
        socc = snap.pop("spgemm_occupancy_sum", 0.0)
        snap["spgemm_occupancy"] = socc / bw if bw else 0.0
        ns = max(1, snap.get("spgemm_requests", 0))
        snap["spgemm_latency_s_per_request"] = (
            snap.get("spgemm_queue_s", 0.0)
            + snap.get("spgemm_compute_s", 0.0)) / ns
        snap["structure_cache"] = self._engine.structure_cache.stats()
        return snap


@dataclasses.dataclass
class SparseGemmRequest:
    """One pending sparse multiply: ELLPACK operands + timing bookkeeping."""
    rid: int
    a: object                   # EllRows
    b: object                   # EllCols
    t_enq: float
    t_done: float = 0.0
    result: Optional[object] = None


class SparseGemmBatcher:
    """Continuous batching of heterogeneous sparse requests onto SpGEMM slots.

    ``submit`` enqueues one ``C = A·B``; ``flush`` drains the queue: requests
    are grouped by operand *shape* signature (patterns — fingerprints — may
    differ freely within a group: each batched slot carries its own
    structure key plane), their structures come from / return to the shared
    ``StructureCache`` (one symbolic phase per distinct fingerprint across
    the whole engine lifetime), and every group runs in waves of
    ``max_slots`` through ``spgemm_coo_numeric_batched`` — one compiled
    program per shape signature, slots padded with a repeated request so
    shapes stay static. Singleton waves skip the batch machinery
    (``spgemm_coo_numeric``).

    ``stats`` (any dict; the engine passes its :class:`EngineStats`) gains
    ``spgemm_requests`` / ``spgemm_waves`` / ``spgemm_batched_waves``
    counters, ``spgemm_occupancy_sum`` (real slots over ``max_slots``, per
    batched wave) and per-request ``spgemm_queue_s`` / ``spgemm_compute_s``
    latency totals.
    """

    _STAT_INTS = ("spgemm_requests", "spgemm_waves", "spgemm_batched_waves")
    _STAT_FLOATS = ("spgemm_occupancy_sum", "spgemm_queue_s",
                    "spgemm_compute_s")

    def __init__(self, cache, *, max_slots: int = 8, stats=None):
        self.cache = cache
        self.max_slots = max(1, int(max_slots))
        self.stats = stats if stats is not None else {}
        for k in self._STAT_INTS:
            self.stats.setdefault(k, 0)
        for k in self._STAT_FLOATS:
            self.stats.setdefault(k, 0.0)
        self._pending: List[SparseGemmRequest] = []
        self._next_rid = 0

    def submit(self, a, b) -> int:
        """Enqueue C = A·B (row-wise ELLPACK × col-wise ELLPACK); returns
        a request id to look the result up with after ``flush``."""
        rid = self._next_rid
        self._next_rid += 1
        self._pending.append(SparseGemmRequest(rid, a, b, time.time()))
        self.stats["spgemm_requests"] += 1
        _obs_metrics.inc("serve.spgemm_submits")
        return rid

    def pending(self) -> int:
        return len(self._pending)

    def flush(self, **structure_kwargs) -> Dict[int, object]:
        """Run every pending request; returns {rid: sorted-COO result}.

        ``structure_kwargs`` forward to the structure build on a cache miss
        (``backend=``, ``out_cap=``, ...)."""
        reqs, self._pending = self._pending, []
        out: Dict[int, object] = {}
        groups: Dict[tuple, List[SparseGemmRequest]] = {}
        for r in reqs:
            sig = (r.a.n_rows, r.a.n_cols, r.a.k, r.b.n_cols, r.b.k,
                   str(r.a.val.dtype), str(r.b.val.dtype))
            groups.setdefault(sig, []).append(r)
        for members in groups.values():
            t0 = time.time()
            for r in members:
                self.stats["spgemm_queue_s"] += t0 - r.t_enq
            # structure recycling: one symbolic phase per fingerprint,
            # shared across requests/waves/flushes via the engine cache
            sts = [self.cache.get(r.a, r.b, **structure_kwargs)
                   for r in members]
            for lo in range(0, len(members), self.max_slots):
                self._run_wave(members[lo:lo + self.max_slots],
                               sts[lo:lo + self.max_slots], out)
        return out

    def _run_wave(self, wave, wsts, out) -> None:
        from repro.core.spgemm import (spgemm_coo_numeric,
                                       spgemm_coo_numeric_batched)
        t0 = time.time()
        self.stats["spgemm_waves"] += 1
        batched = len(wave) > 1
        with _obs.span("serve.spgemm_wave", real=len(wave),
                       slots=self.max_slots if batched else 1,
                       batched=batched):
            if not batched:
                r, st = wave[0], wsts[0]
                # the cache key already proved the fingerprint matches
                r.result = spgemm_coo_numeric(r.a, r.b, st, validate=False)
            else:
                a_b, b_b, st_b = self._pack(wave, wsts)
                coo = spgemm_coo_numeric_batched(a_b, b_b, st_b,
                                                 validate=False)
                for i, r in enumerate(wave):
                    r.result = type(coo)(
                        row=coo.row[i], col=coo.col[i], val=coo.val[i],
                        shape=coo.shape, ngroups=coo.ngroups[i])
                occ = len(wave) / self.max_slots
                self.stats["spgemm_batched_waves"] += 1
                self.stats["spgemm_occupancy_sum"] += occ
                _obs_metrics.gauge("serve.spgemm_occupancy", occ)
            _obs.sync(wave[-1].result.val)
        t1 = time.time()
        for r in wave:
            r.t_done = t1
            self.stats["spgemm_compute_s"] += t1 - t0
            _obs_metrics.observe("serve.spgemm_request_us",
                                 (r.t_done - r.t_enq) * 1e6)
            out[r.rid] = r.result

    def _pack(self, wave, wsts):
        """Stack a wave onto ``max_slots`` static slots: operands stacked
        with request 0 repeated into the tail slots, per-slot key planes
        padded to the widest structure's ``out_cap`` with ``KEY_INVALID``
        (keys stay ascending, so the numeric searchsorted is unaffected)."""
        from repro.kernels.bitonic_merge import KEY_INVALID
        from repro.plan.structure import SpgemmStructure

        def pad_reqs(xs):
            return xs + [xs[0]] * (self.max_slots - len(xs))

        reqs, sts = pad_reqs(list(wave)), pad_reqs(list(wsts))
        cap = max(st.out_cap for st in sts)

        def pad_key(k):
            if k.shape[0] == cap:
                return k
            return jnp.concatenate(
                [k, jnp.full((cap - k.shape[0],), KEY_INVALID, k.dtype)])

        a0, b0 = reqs[0].a, reqs[0].b
        a_b = type(a0)(val=jnp.stack([r.a.val for r in reqs]),
                       idx=jnp.stack([r.a.idx for r in reqs]),
                       n_rows=a0.n_rows)
        b_b = type(b0)(val=jnp.stack([r.b.val for r in reqs]),
                       idx=jnp.stack([r.b.idx for r in reqs]),
                       n_cols=b0.n_cols)
        st_b = SpgemmStructure(
            key=jnp.stack([pad_key(st.key) for st in sts]),
            row_nnz=jnp.stack([st.row_nnz for st in sts]),
            seg=jnp.stack([st.seg for st in sts]),
            nnz=jnp.stack([st.nnz for st in sts]),
            n_rows=sts[0].n_rows, n_cols=sts[0].n_cols, out_cap=cap,
            fp=None, plan=None)
        return a_b, b_b, st_b


class ServingEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.s_max))
        self._rng = np.random.default_rng(cfg.seed)
        from repro.plan.cache import StructureCache
        self.structure_cache = StructureCache(
            capacity=cfg.structure_cache_size,
            cache_dir=cfg.structure_cache_dir,
            autotune=cfg.structure_autotune)
        self.stats = EngineStats(self)
        # heterogeneous sparse-request batching over the same cache/stats
        self.sparse_batcher = SparseGemmBatcher(
            self.structure_cache, max_slots=cfg.max_batch, stats=self.stats)

    def spgemm(self, a, b, **structure_kwargs):
        """Two-phase SpGEMM through the engine's shared structure cache.

        Any sparse multiply issued on behalf of a request (sparse-FFN
        applies, GNN-style feature propagation) lands here: the first
        request with a given sparsity pattern pays the symbolic phase, every
        subsequent request — across the whole engine lifetime, and across
        restarts when ``structure_cache_dir`` is set — runs numeric-only.
        ``structure_kwargs`` forward to the structure build on a miss."""
        from repro.core.spgemm import spgemm_coo_numeric
        structure = self.structure_cache.get(a, b, **structure_kwargs)
        # the cache key already proved the fingerprint matches
        return spgemm_coo_numeric(a, b, structure, validate=False)

    def submit_spgemm(self, a, b) -> int:
        """Enqueue a sparse multiply for slot-batched execution; returns the
        request id ``flush_spgemm``'s result dict is keyed by."""
        return self.sparse_batcher.submit(a, b)

    def flush_spgemm(self, **structure_kwargs) -> Dict[int, object]:
        """Drain the sparse-request queue through batched numeric SpGEMM
        (see :class:`SparseGemmBatcher`); occupancy and latency land in
        ``self.stats``."""
        return self.sparse_batcher.flush(**structure_kwargs)

    def cache_stats(self):
        """Structure-cache counters (hits/misses/evictions/disk_hits/size)
        alongside the serving counters in ``self.stats``."""
        return self.structure_cache.stats()

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.cfg.greedy:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / max(self.cfg.temperature, 1e-3)
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self._rng.choice(len(q), p=q) for q in p],
                        dtype=np.int32)

    def generate_batch(self, prompts: List[np.ndarray]) -> List[List[int]]:
        """Serve one admission wave of ≤ max_batch prompts to completion."""
        cfg = self.cfg
        assert len(prompts) <= cfg.max_batch
        b = len(prompts)
        t_enq = time.time()
        reqs = [Request(i, p, t_enq=t_enq) for i, p in enumerate(prompts)]
        plen = max(len(p) for p in prompts)
        toks = np.full((b, plen), cfg.eos_id, np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p      # left-pad so last pos = last token
        t0 = time.time()
        # admission → prefill-start is this engine's queue phase
        self.stats["queue_s"] += (t0 - t_enq) * b
        _obs_metrics.observe("serve.queue_us", (t0 - t_enq) * 1e6)
        with _obs.span("serve.prefill", batch=b, prompt_len=plen):
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(toks)})
            _obs.sync(logits)
        self.stats["prefill_s"] += time.time() - t0
        self.stats["requests"] += b
        # the first sampled token is a real emission: count it and honour EOS
        # so an immediately-finished request never enters the decode loop
        cur = self._sample(np.asarray(logits, np.float32))
        alive = False
        for r, t in zip(reqs, cur):
            r.out_tokens.append(int(t))
            self.stats["tokens"] += 1
            if t == cfg.eos_id:
                r.done = True
                r.t_done = time.time()
            else:
                alive = True
        t0 = time.time()
        steps = 0
        with _obs.span("serve.decode", batch=b) as _dsp:
            for _ in range(cfg.max_new_tokens - 1):
                if not alive:
                    break
                n_alive = sum(not r.done for r in reqs)
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(cur)[:, None])
                cur = self._sample(np.asarray(logits, np.float32))
                steps += 1
                # occupancy = live slots over the engine's static batch grid
                self.stats["occupancy_sum"] += n_alive / cfg.max_batch
                self.stats["decode_steps"] += 1
                _obs_metrics.gauge("serve.batch_occupancy",
                                   n_alive / cfg.max_batch)
                alive = False
                for r, t in zip(reqs, cur):
                    if r.done:
                        continue
                    r.out_tokens.append(int(t))
                    self.stats["tokens"] += 1
                    if t == cfg.eos_id:
                        r.done = True
                        r.t_done = time.time()
                    else:
                        alive = True
            _dsp.set(steps=steps)
        self.stats["decode_s"] += time.time() - t0
        t_end = time.time()
        for r in reqs:
            if not r.done:
                r.t_done = t_end
            compute_s = r.t_done - r.t_enq
            self.stats["compute_s"] += compute_s
            _obs_metrics.observe("serve.compute_us", compute_s * 1e6)
        return [r.out_tokens for r in reqs]
