"""Pallas TPU kernel: SCCP slab-pair structured multiply (paper Fig. 8).

The hot inner loop of SPLIM's multiply phase: every (A row-slab, B col-slab)
pair combined element-wise along the shared axis. On the memristor array this
is one in-situ ⊙ over all lanes; on TPU v5e we tile the lane axis ``n`` into
VMEM blocks (lane-dim multiple of 128 for VREG alignment) and let the VPU
stream the broadcasted product. Slab counts (k_a, k_b) are small (ELLPACK
widths), so they ride whole in each block.

Memory layout per grid step (lane tile of size BN):
    a_val/a_idx : (k_a, BN)   VMEM
    b_val/b_idx : (BN, k_b)   VMEM
    out         : (k_a, BN, k_b) val/row/col  VMEM
VMEM working set = BN·(2·k_a + 2·k_b + 3·k_a·k_b)·4B — BN=512, k=32 →
~6.5 MB, inside the 16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INVALID = -1
LANE_BLOCK = 512  # multiple of 128 (VREG lane width)


def _sccp_kernel(a_val_ref, a_idx_ref, b_val_ref, b_idx_ref,
                 val_ref, row_ref, col_ref):
    a_val = a_val_ref[...]            # (k_a, BN)
    a_idx = a_idx_ref[...]
    b_val = b_val_ref[...]            # (BN, k_b)
    b_idx = b_idx_ref[...]
    val = a_val[:, :, None] * b_val[None, :, :]
    row = jnp.broadcast_to(a_idx[:, :, None], val.shape)
    col = jnp.broadcast_to(b_idx[None, :, :], val.shape)
    ok = jnp.logical_and(row >= 0, col >= 0)
    val_ref[...] = jnp.where(ok, val, 0)
    row_ref[...] = jnp.where(ok, row, INVALID)
    col_ref[...] = jnp.where(ok, col, INVALID)


def auto_interpret() -> bool:
    """Interpret only where the Pallas TPU lowering is unavailable.

    The compiled path is the point of writing kernels; interpret mode is the
    CPU/debug fallback, orders of magnitude slower. Resolved at trace time,
    so jitted callers bake in the right choice for the backend they compile
    for.
    """
    return jax.default_backend() != "tpu"


def sccp_multiply_pallas(a_val: jax.Array, a_idx: jax.Array,
                         b_val: jax.Array, b_idx: jax.Array,
                         *, block_n: int = LANE_BLOCK,
                         interpret: bool | None = None):
    """Tiled SCCP multiply. Shapes: a (k_a, n), b (n, k_b); n % block_n == 0.

    Returns (val, row, col) each (k_a, n, k_b). ``interpret=None`` (default)
    auto-selects: compiled on TPU, interpreter elsewhere (``auto_interpret``).
    """
    if interpret is None:
        interpret = auto_interpret()
    return _sccp_multiply_jit(a_val, a_idx, b_val, b_idx,
                              block_n=block_n, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _sccp_multiply_jit(a_val: jax.Array, a_idx: jax.Array,
                       b_val: jax.Array, b_idx: jax.Array,
                       *, block_n: int, interpret: bool):
    k_a, n = a_val.shape
    n2, k_b = b_val.shape
    assert n == n2, (n, n2)
    assert n % block_n == 0, f"n={n} must be a multiple of block_n={block_n}"
    grid = (n // block_n,)
    out_shape = [
        jax.ShapeDtypeStruct((k_a, n, k_b), a_val.dtype),
        jax.ShapeDtypeStruct((k_a, n, k_b), jnp.int32),
        jax.ShapeDtypeStruct((k_a, n, k_b), jnp.int32),
    ]
    return pl.pallas_call(
        _sccp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k_a, block_n), lambda i: (0, i)),
            pl.BlockSpec((k_a, block_n), lambda i: (0, i)),
            pl.BlockSpec((block_n, k_b), lambda i: (i, 0)),
            pl.BlockSpec((block_n, k_b), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k_a, block_n, k_b), lambda i: (0, i, 0)),
            pl.BlockSpec((k_a, block_n, k_b), lambda i: (0, i, 0)),
            pl.BlockSpec((k_a, block_n, k_b), lambda i: (0, i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(a_val, a_idx, b_val, b_idx)
