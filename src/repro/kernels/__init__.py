"""Pallas TPU kernels for SPLIM's compute hot-spots (validated interpret=True).

  sccp_multiply   — structured slab-pair multiply (paper Fig. 8), VMEM-tiled
  fused_sccp_stream — one streaming step fused: slab multiply + packed-key
                    bitonic sort entirely in VMEM (feeds core/streaming)
  bitonic_merge   — sort + segmented-sum: the in-situ search's batched dual
  radix_bucket    — propagation-blocking accumulation (bin by row range,
                    per-bucket bitonic sort/reduce)
  hash_accum      — per-row-block open-addressing hash accumulation
  insitu_search   — the paper's Algorithm 1 itself (bit-serial minima search)
  ell_spmm        — ELLPACK × dense via one-hot MXU tiles (MoE/SparseLinear)
  ops             — jit'd public wrappers (padding, fallbacks)
  ref             — pure-jnp oracles for every kernel
"""
from . import ops, ref

__all__ = ["ops", "ref"]
