"""Public jit'd wrappers around the Pallas kernels.

Handle padding/alignment (lane tiles multiple of 128, power-of-2 merge
tiles), choose interpret mode off-TPU, and fall back to the jnp reference
where a kernel's structural preconditions can't be met (e.g. coordinate
space too large for 32-bit packed keys).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .bitonic_merge import KEY_INVALID, bitonic_merge_pallas, sort_merge_tree_pallas
from .ell_spmm import BM, BN, ell_spmm_pallas
from .sccp_multiply import LANE_BLOCK, sccp_multiply_pallas

INVALID = -1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, fill):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def sccp_multiply(a_val, a_idx, b_val, b_idx, *, block_n: int | None = None):
    """Tiled SCCP multiply; pads the lane axis to the VMEM block size."""
    n = a_val.shape[1]
    bn = block_n or min(LANE_BLOCK, max(128, 1 << (n - 1).bit_length()))
    a_val_p = _pad_to(a_val, 1, bn, 0)
    a_idx_p = _pad_to(a_idx, 1, bn, INVALID)
    b_val_p = _pad_to(b_val, 0, bn, 0)
    b_idx_p = _pad_to(b_idx, 0, bn, INVALID)
    val, row, col = sccp_multiply_pallas(
        a_val_p, a_idx_p, b_val_p, b_idx_p,
        block_n=bn, interpret=not _on_tpu())
    return val[:, :n, :], row[:, :n, :], col[:, :n, :]


def sort_merge(row, col, val, n_rows: int, n_cols: int, *, tile: int = 4096):
    """Coalesce duplicate coordinates: sorted keys + run-tail totals.

    Packs (row, col) into one int32 key when the coordinate space fits
    (n_rows·n_cols < 2³¹ — always true for the tile-local merges the kernel
    is built for); otherwise falls back to the reference path on the
    unpacked planes (documented structural precondition).

    Streams up to one ``tile`` run the single bitonic network; larger
    streams go through the multi-tile merge tree (sort VMEM-sized tiles
    independently, pairwise-merge sorted runs up the tree) so the k_a·n·k_b
    product stream never has to fit one monolithic power-of-two network.
    """
    row = row.reshape(-1)
    col = col.reshape(-1)
    val = val.reshape(-1)
    n = row.shape[0]
    pot = 1 << (n - 1).bit_length()
    if n_rows * n_cols >= jnp.iinfo(jnp.int32).max:
        from repro.core.accumulate import sort_by_coords
        r, c, v = sort_by_coords(row, col, val, n_rows)
        key = jnp.where(r >= 0, r * n_cols + c, KEY_INVALID)
        return ref.bitonic_merge_ref(key, v)
    key = jnp.where(row >= 0, row * n_cols + col, KEY_INVALID).astype(jnp.int32)
    key = _pad_to(key, 0, pot, KEY_INVALID)[:pot]
    val = _pad_to(val, 0, pot, 0.0)[:pot]
    return sort_merge_tree_pallas(key, val, tile=tile,
                                  interpret=not _on_tpu())


def ell_spmm(a_val, a_idx, x, n_rows: int, *, d_chunk: int = 512):
    """A(ELL rows) @ X with padding to MXU tiles and D chunking."""
    k, n = a_val.shape
    a_val_p = _pad_to(a_val, 1, BN, 0)
    a_idx_p = _pad_to(a_idx, 1, BN, INVALID)
    x_p = _pad_to(x, 0, BN, 0)
    m_pad = n_rows + ((-n_rows) % BM)
    d = x.shape[-1]
    outs = []
    for lo in range(0, d, d_chunk):
        xc = x_p[:, lo:lo + d_chunk]
        outs.append(ell_spmm_pallas(a_val_p, a_idx_p, xc, n_rows=m_pad,
                                    interpret=not _on_tpu()))
    out = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
    return out[:n_rows]
