"""Public jit'd wrappers around the Pallas kernels.

Handle padding/alignment (lane tiles multiple of 128, power-of-2 merge
tiles), choose interpret mode off-TPU, and fall back to the jnp reference
where a kernel's structural preconditions can't be met (e.g. coordinate
space too large for 32-bit packed keys).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import fused_sccp_stream, hash_accum, insitu_search, radix_bucket
from .bitonic_merge import KEY_INVALID, bitonic_merge_pallas, sort_merge_tree_pallas
from .ell_spmm import BM, BN, ell_spmm_pallas
from .sccp_multiply import LANE_BLOCK, sccp_multiply_pallas

INVALID = -1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to(x: jax.Array, axis: int, mult: int, fill):
    """Pad ``x`` along ``axis`` (negative ok) up to a multiple of ``mult``
    with ``fill`` — the shared alignment helper (kernel lane tiles, merge
    tiles, distributed slab padding)."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


def sccp_multiply(a_val, a_idx, b_val, b_idx, *, block_n: int | None = None):
    """Tiled SCCP multiply; pads the lane axis to the VMEM block size."""
    n = a_val.shape[1]
    bn = block_n or min(LANE_BLOCK, max(128, 1 << (n - 1).bit_length()))
    a_val_p = pad_to(a_val, 1, bn, 0)
    a_idx_p = pad_to(a_idx, 1, bn, INVALID)
    b_val_p = pad_to(b_val, 0, bn, 0)
    b_idx_p = pad_to(b_idx, 0, bn, INVALID)
    val, row, col = sccp_multiply_pallas(
        a_val_p, a_idx_p, b_val_p, b_idx_p, block_n=bn)  # interpret auto
    return val[:, :n, :], row[:, :n, :], col[:, :n, :]


def fused_slab_sort(a_val, a_idx, b_val, b_idx, *, n_cols: int):
    """One streaming step: slab products → sorted packed keys + run totals.

    On TPU the fused Pallas kernel keeps the raw product tile in VMEM
    (kernels/fused_sccp_stream); elsewhere the identical contract goes
    through XLA's fused sort — NOT interpret-mode Pallas, which would put an
    interpreter inside the streaming engine's innermost scan loop.
    Coordinate spaces ≥ 2³¹ can't pack (callers route those to the unpacked
    two-key 'sort' path, as spgemm_coo does automatically).
    """
    if _on_tpu():
        return fused_sccp_stream.fused_slab_sort_pallas(
            a_val, a_idx, b_val, b_idx, n_cols=n_cols)  # interpret auto
    return fused_sccp_stream.fused_slab_sort_xla(
        a_val, a_idx, b_val, b_idx, n_cols=n_cols)


def sort_merge(row, col, val, n_rows: int, n_cols: int, *, tile: int = 4096):
    """Coalesce duplicate coordinates: sorted keys + run-tail totals.

    Packs (row, col) into one int32 key; coordinate spaces with
    n_rows·n_cols ≥ 2³¹ cannot be represented in packed keys at all (the
    unpack in downstream compaction would wrap too) and raise — route those
    through the unpacked two-key path (core.accumulate), as spgemm_coo does
    automatically (documented structural precondition).

    Streams up to one ``tile`` run the single bitonic network; larger
    streams go through the multi-tile merge tree (sort VMEM-sized tiles
    independently, pairwise-merge sorted runs up the tree) so the k_a·n·k_b
    product stream never has to fit one monolithic power-of-two network.
    """
    packed = _packed_stream(row, col, val, n_rows, n_cols)
    if packed is None:
        _unpackable(n_rows, n_cols)
    key, val = packed
    return sort_merge_tree_pallas(key, val, tile=tile,
                                  interpret=not _on_tpu())


def _packed_stream(row, col, val, n_rows: int, n_cols: int):
    """Flatten + pack coordinates to int32 keys, padded to a power of two.

    Returns ``None`` when the coordinate space doesn't fit packed 32-bit
    keys (callers raise via ``_unpackable`` — the structural precondition
    ``sort_merge`` documents; the unpacked two-key sort in core.accumulate
    is the path for such spaces).
    """
    if n_rows * n_cols >= jnp.iinfo(jnp.int32).max:
        return None
    row = row.reshape(-1)
    col = col.reshape(-1)
    val = val.reshape(-1)
    pot = 1 << (row.shape[0] - 1).bit_length()
    key = jnp.where(row >= 0, row * n_cols + col, KEY_INVALID).astype(jnp.int32)
    key = pad_to(key, 0, pot, KEY_INVALID)[:pot]
    val = pad_to(val, 0, pot, 0.0)[:pot]
    return key, val


def _unpackable(n_rows: int, n_cols: int):
    raise ValueError(
        f"coordinate space {n_rows}x{n_cols} exceeds packed int32 keys; "
        "use the unpacked two-key path (core.accumulate / "
        "spgemm_coo(accumulator='sort')) — spgemm_coo routes there "
        "automatically")


def search_merge(row, col, val, n_rows: int, n_cols: int, *,
                 out_cap: int, interpret: bool | None = None,
                 faithful: bool = False):
    """The paper's in-situ-search accumulation (Alg. 1 / Fig. 11): emit the
    sorted unique coordinate list, then align every product against it.

    Two passes over the packed stream: ``insitu_search.emit_sorted_unique``
    produces the sorted unique keys (batched key-only network, or the
    literal iterated Alg. 1 scan with ``faithful=True``), and
    ``insitu_search.align_keys`` locates each product's slot in that list
    (CAM-style broadcast compare on the Pallas path, ``searchsorted`` on
    XLA) — no re-sort of the value lanes at all, which is exactly where
    this backend beats 'sort' on duplicate-heavy streams. One segment-sum
    lands the values.

    Returns ``(uk, sums, nnz)``: the (out_cap,) sorted unique keys with
    KEY_INVALID padding, the per-slot value totals, and the TRUE unique
    count (``nnz > out_cap`` flags truncation; the kept slots are the first
    ``out_cap`` unique keys, matching the 'sort' backend's truncation
    order). Coordinate spaces ≥ 2³¹ can't pack and raise, like the other
    packed-key backends (spgemm_coo reroutes those to 'sort').
    """
    packed = _packed_stream(row, col, val, n_rows, n_cols)
    if packed is None:
        _unpackable(n_rows, n_cols)
    key, v = packed
    uk, nnz = insitu_search.emit_sorted_unique(
        key, out_cap, interpret=interpret, faithful=faithful)
    slot, hit = insitu_search.align_keys(key, uk, interpret=interpret)
    ok = jnp.logical_and(key != KEY_INVALID, hit)
    slot = jnp.where(ok, slot, out_cap)
    sums = jax.ops.segment_sum(jnp.where(ok, v, 0), slot,
                               num_segments=out_cap + 1)[:out_cap]
    return uk, sums, nnz


def bucket_merge(row, col, val, n_rows: int, n_cols: int, *,
                 n_buckets: int | None = None,
                 bucket_cap: int | None = None):
    """Propagation-blocking coalesce: bin by row range, sort each bucket.

    Returns ``(key_sorted, totals, dropped)`` — same stream contract as
    ``sort_merge`` plus the count of products lost to full buckets
    (``dropped == 0`` when ``bucket_cap`` was planner-sized). Without an
    explicit ``bucket_cap`` every bucket must be able to hold the whole
    stream (worst-case skew), so the no-argument default is ONE
    stream-sized bucket; multi-bucket blocking with tight caps comes from
    plan.make_plan — asking for ``n_buckets`` alone costs n_buckets× the
    stream in memory and sort width.
    """
    if n_buckets is None and bucket_cap is None:
        n_buckets = 1
    n_buckets = n_buckets or 8
    packed = _packed_stream(row, col, val, n_rows, n_cols)
    if packed is None:
        _unpackable(n_rows, n_cols)
    key, val = packed
    cap = bucket_cap or key.shape[0]
    if cap & (cap - 1):
        raise ValueError(f"bucket_cap must be a power of two, got {cap}")
    kpb = radix_bucket.bucket_bounds(n_rows, n_cols, n_buckets)
    # interpret auto: compiled Pallas on TPU, XLA realization elsewhere
    return radix_bucket.bucket_merge(key, val, n_buckets=n_buckets,
                                     bucket_cap=cap, keys_per_bucket=kpb)


def hash_merge(row, col, val, n_rows: int, n_cols: int, *,
               n_blocks: int | None = None, block_cap: int | None = None,
               max_probes: int | None = None):
    """Hash-accumulate into per-row-block open-addressing tables.

    Returns ``(key_sorted, totals, dropped)`` — the sorted *tables*, not the
    stream, so the bitonic pass is table-sized. ``dropped`` counts probe/
    table exhaustion (0 with planner-sized ``block_cap``). As with
    ``bucket_merge``, the no-argument default is ONE stream-sized table;
    tight multi-block caps come from plan.make_plan.
    """
    if n_blocks is None and block_cap is None:
        n_blocks = 1
    n_blocks = n_blocks or 8
    packed = _packed_stream(row, col, val, n_rows, n_cols)
    if packed is None:
        _unpackable(n_rows, n_cols)
    key, val = packed
    cap = block_cap or key.shape[0]
    if cap & (cap - 1):
        raise ValueError(f"block_cap must be a power of two, got {cap}")
    kpb = radix_bucket.bucket_bounds(n_rows, n_cols, n_blocks)
    # interpret auto: compiled Pallas on TPU, XLA realization elsewhere
    return hash_accum.hash_merge(key, val, n_blocks=n_blocks, block_cap=cap,
                                 keys_per_block=kpb, max_probes=max_probes)


def ell_spmm(a_val, a_idx, x, n_rows: int, *, d_chunk: int = 512):
    """A(ELL rows) @ X with padding to MXU tiles and D chunking."""
    k, n = a_val.shape
    a_val_p = pad_to(a_val, 1, BN, 0)
    a_idx_p = pad_to(a_idx, 1, BN, INVALID)
    x_p = pad_to(x, 0, BN, 0)
    m_pad = n_rows + ((-n_rows) % BM)
    d = x.shape[-1]
    outs = []
    for lo in range(0, d, d_chunk):
        xc = x_p[:, lo:lo + d_chunk]
        outs.append(ell_spmm_pallas(a_val_p, a_idx_p, xc, n_rows=m_pad,
                                    interpret=not _on_tpu()))
    out = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
    return out[:n_rows]
