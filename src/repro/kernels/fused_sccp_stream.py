"""Pallas TPU kernel: fused SCCP slab multiply + in-VMEM tile sort.

One streaming step of the paper's Fig. 8 iteration realized as a single
kernel: the products of one A slab against all B slabs are formed, packed
into coordinate keys and bitonic-sorted **without ever leaving VMEM** — the
raw (n, k_b) product tile never touches HBM on the compiled path. Output is
the ``bitonic_merge`` stream contract (ascending keys, invalid lanes parked
at INT32_MAX, run-tail totals), which the streaming accumulation engine
(core/streaming.py) compacts and merges into its running buffer.

This is the fusion ``kernels/sccp_multiply.py`` stops short of: that kernel
emits the raw product tile to HBM (12 B/lane, mostly ELLPACK-padding
INVALID lanes) for a later global sort; here multiply → pack → sort → run
totals happen in one VMEM residency, so the per-step HBM traffic is the
operand slabs in and one sorted pot(n·k_b) stream out.

Off-TPU the same contract is realized by ``fused_slab_sort_xla`` — packed
keys through XLA's fused ``lax.sort`` plus the log-step segmented total —
because interpret-mode Pallas would put an interpreter in the innermost
scan loop (kernels/ops.fused_slab_sort picks per backend).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitonic_merge import (KEY_INVALID, _bitonic_sort_rows,
                            _segmented_total_rows, next_pot as _pot)

INVALID = -1


def _pack_tile(a_val, a_idx, b_val, b_idx, n_cols: int, pot_len: int):
    """Slab products → packed int32 keys + values, padded to ``pot_len``.

    a_val/a_idx: (n,) one A slab; b_val/b_idx: (n, k_b) all B slabs.
    Shared jnp body of the Pallas kernel and the XLA fallback.
    """
    val = a_val[:, None] * b_val                       # (n, k_b)
    row = jnp.broadcast_to(a_idx[:, None], val.shape)
    ok = jnp.logical_and(row >= 0, b_idx >= 0)
    key = jnp.where(ok, row * n_cols + b_idx, KEY_INVALID).astype(jnp.int32)
    val = jnp.where(ok, val, 0)
    key = key.reshape(1, -1)
    val = val.reshape(1, -1)
    pad = pot_len - key.shape[-1]
    if pad:
        key = jnp.concatenate(
            [key, jnp.full((1, pad), KEY_INVALID, key.dtype)], axis=-1)
        val = jnp.concatenate(
            [val, jnp.zeros((1, pad), val.dtype)], axis=-1)
    return key, val


def _make_fused_kernel(n_cols: int, pot_len: int):
    def kernel(a_val_ref, a_idx_ref, b_val_ref, b_idx_ref,
               key_ref, tot_ref):
        key, val = _pack_tile(a_val_ref[...].reshape(-1),
                              a_idx_ref[...].reshape(-1),
                              b_val_ref[...], b_idx_ref[...],
                              n_cols, pot_len)
        key, val = _bitonic_sort_rows(key, val)
        tot = _segmented_total_rows(key, val)
        key_ref[...] = key.reshape(key_ref.shape)
        tot_ref[...] = tot.reshape(tot_ref.shape)
    return kernel


def fused_slab_sort_pallas(a_val: jax.Array, a_idx: jax.Array,
                           b_val: jax.Array, b_idx: jax.Array, *,
                           n_cols: int, interpret: bool | None = None):
    """Fused multiply+sort of one slab tile, entirely in VMEM.

    ``a_val``/``a_idx``: (n,) — one A slab; ``b_val``/``b_idx``: (n, k_b).
    Returns ``(key, tot)`` of length ``pot(n·k_b)``: ascending packed
    coordinate keys (invalid = INT32_MAX) with run-tail totals.
    Requires ``n_rows·n_cols < 2³¹`` (packed int32 keys).
    ``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        from .sccp_multiply import auto_interpret
        interpret = auto_interpret()
    return _fused_slab_sort_jit(a_val, a_idx, b_val, b_idx, n_cols=n_cols,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_cols", "interpret"))
def _fused_slab_sort_jit(a_val: jax.Array, a_idx: jax.Array,
                         b_val: jax.Array, b_idx: jax.Array, *,
                         n_cols: int, interpret: bool):
    n, k_b = b_val.shape
    pot_len = _pot(n * k_b)
    # one whole-tile block: slab counts are ELLPACK widths (small), and the
    # sort network needs the full tile resident anyway
    return pl.pallas_call(
        _make_fused_kernel(n_cols, pot_len),
        out_shape=[jax.ShapeDtypeStruct((pot_len,), jnp.int32),
                   jax.ShapeDtypeStruct((pot_len,), a_val.dtype)],
        interpret=interpret,
    )(a_val, a_idx, b_val, b_idx)


@functools.partial(jax.jit, static_argnames=("n_cols",))
def fused_slab_sort_xla(a_val: jax.Array, a_idx: jax.Array,
                        b_val: jax.Array, b_idx: jax.Array, *,
                        n_cols: int):
    """Same contract through XLA's fused sort (the off-TPU realization)."""
    n, k_b = b_val.shape
    pot_len = _pot(n * k_b)
    key, val = _pack_tile(a_val, a_idx, b_val, b_idx, n_cols, pot_len)
    key, val = key.reshape(-1), val.reshape(-1)
    key, val = jax.lax.sort((key, val), dimension=0, num_keys=1,
                            is_stable=False)
    tot = _segmented_total_rows(key[None, :], val[None, :])[0]
    return key, tot
