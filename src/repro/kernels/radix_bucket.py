"""Propagation-blocking accumulation: bin the product stream by row range,
then sort/reduce every bucket independently (cf. Gu et al., "Bandwidth-
Optimized Parallel Algorithms for SpGEMM using Propagation Blocking").

The monolithic sort paths (core/accumulate, the bitonic merge tree) touch the
whole k_a·n·k_b product stream at every network level. Propagation blocking
replaces the global pass with two bandwidth-friendly ones:

  1. **Stable binning** — one linear sweep assigns every product to the bucket
     that owns its output-row range and writes it at ``(bucket, rank)`` where
     ``rank`` is the running per-bucket count. Ranks come from a chunked scan
     carrying one (n_buckets,) counter vector (``bin_ranks_pallas``): each
     chunk does a one-hot cumsum in VMEM, gather-free — the rank readback is a
     masked row-sum, not a dynamic gather (the 0.4.37 toolchain compiles 1-D
     gathers over long unrolled programs in minutes).
  2. **Per-bucket sort+coalesce** — every bucket is a power-of-2 tile, so ALL
     buckets ride the batch axis of ONE bitonic network
     (``bitonic_merge.sort_tiles_pallas``), working-set bounded by
     n_buckets-way blocking exactly like ``spgemm_streaming`` bounds the
     multiply — but the output stays sparse COO, not dense.

Because buckets partition the *key range* (contiguous output-row spans),
concatenating sorted buckets in bucket order is globally sorted: a run of
equal keys can never straddle a bucket boundary, and the KEY_INVALID padding
parked at each bucket tail is exactly what the downstream compaction
(`spgemm._coo_from_merged`) already skips.

Bucket capacity is static (JAX shapes). Products that land beyond a full
bucket are *dropped and counted* — callers surface ``dropped`` by poisoning
``Coo.ngroups`` so the existing overflow machinery (``check_no_overflow`` /
``overflowed()``) reports it; the planner sizes ``bucket_cap`` from an exact
per-bucket histogram so the planned path never drops.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitonic_merge import (KEY_INVALID, resolve_mode, sort_tiles_pallas,
                            sort_tiles_xla)

_RANK_CHUNK = 1024


def _make_rank_kernel(n_buckets: int, chunk: int):
    """Per-element rank within its bucket via a chunked one-hot cumsum scan.

    Carry is the (n_buckets,) element count seen so far; within a chunk the
    inclusive one-hot cumsum gives local ranks and the rank readback is a
    masked row-sum (no gather). Invalid lanes (bid < 0) match no one-hot
    column and rank -1, which the binning scatter parks in the dump slot.
    """
    def kernel(bid_ref, rank_out_ref):
        bid = bid_ref[...].reshape(-1, chunk)
        ids = jnp.arange(n_buckets, dtype=jnp.int32)

        def step(carry, bchunk):
            oh = (bchunk[:, None] == ids[None, :]).astype(jnp.int32)
            incl = jnp.cumsum(oh, axis=0) + carry[None, :]
            rank = jnp.sum(oh * incl, axis=1) - 1
            return carry + jnp.sum(oh, axis=0), rank

        _, ranks = jax.lax.scan(step, jnp.zeros((n_buckets,), jnp.int32), bid)
        rank_out_ref[...] = ranks.reshape(rank_out_ref.shape)
    return kernel


def bin_ranks_pallas(bid: jax.Array, *, n_buckets: int,
                     interpret: bool | None = None) -> jax.Array:
    """Stable-binning ranks: rank[i] = #{j <= i : bid[j] == bid[i]} - 1.

    ``bid`` int32 (-1 = invalid, yields rank -1); length must be a multiple
    of the scan chunk (callers pad — product streams are already padded to a
    power of two for the sort stage). ``interpret=None`` (default)
    auto-selects: compiled on TPU, interpreter elsewhere (the XLA
    realization is ``bin_ranks_xla``; ``bucket_merge`` picks it
    automatically off-TPU).
    """
    if interpret is None:
        from .sccp_multiply import auto_interpret
        interpret = auto_interpret()
    return _bin_ranks_jit(bid, n_buckets=n_buckets, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_buckets", "interpret"))
def _bin_ranks_jit(bid: jax.Array, *, n_buckets: int,
                   interpret: bool) -> jax.Array:
    (n,) = bid.shape
    chunk = min(_RANK_CHUNK, n)
    assert n % chunk == 0, (n, chunk)
    return pl.pallas_call(
        _make_rank_kernel(n_buckets, chunk),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(bid)


@functools.partial(jax.jit, static_argnames=("n_buckets",))
def bin_ranks_xla(bid: jax.Array, *, n_buckets: int) -> jax.Array:
    """XLA realization of ``bin_ranks_pallas``'s exact contract.

    A stable argsort groups equal bucket ids; rank-in-bucket is position
    minus the group's first position (one ``searchsorted`` against the
    sorted ids), scattered back to input order. ``n_buckets`` is accepted
    for signature parity — the rank of an element never depends on it.
    """
    (n,) = bid.shape
    order = jnp.argsort(bid, stable=True)
    sb = bid[order]
    first = jnp.searchsorted(sb, sb, side="left").astype(jnp.int32)
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - first
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    return jnp.where(bid < 0, -1, rank)


def bucket_bounds(n_rows: int, n_cols: int, n_buckets: int) -> int:
    """Keys-per-bucket span: buckets own ``rows_per_bucket`` contiguous
    output rows, i.e. ``rows_per_bucket * n_cols`` contiguous packed keys."""
    rows_per_bucket = -(-n_rows // n_buckets)   # ceil
    return rows_per_bucket * n_cols


def bucket_merge(key: jax.Array, val: jax.Array, *, n_buckets: int,
                 bucket_cap: int, keys_per_bucket: int,
                 interpret: bool | None = None
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Propagation-blocking sort+coalesce of a packed-key product stream.

    key   : (n,) int32 packed row*n_cols+col, KEY_INVALID for dead lanes.
    val   : (n,) float.
    Returns ``(key_sorted, totals, dropped)``: bucket-concatenated globally
    sorted keys with run-tail totals (the ``sort_merge`` output contract,
    with KEY_INVALID runs at each bucket tail), plus the count of products
    dropped by full buckets (0 when ``bucket_cap`` was sized from the true
    histogram — see plan.planner).

    ``interpret=None`` (default) auto-selects the realization of the two
    kernel stages: compiled Pallas on TPU, the XLA equivalents
    (``bin_ranks_xla`` / ``sort_tiles_xla``) elsewhere — never the
    interpreter, which ``interpret=True`` still forces for kernel tests.
    """
    return _bucket_merge_jit(key, val, n_buckets=n_buckets,
                             bucket_cap=bucket_cap,
                             keys_per_bucket=keys_per_bucket,
                             mode=resolve_mode(interpret))


@functools.partial(jax.jit, static_argnames=("n_buckets", "bucket_cap",
                                             "keys_per_bucket", "mode"))
def _bucket_merge_jit(key: jax.Array, val: jax.Array, *, n_buckets: int,
                      bucket_cap: int, keys_per_bucket: int,
                      mode: str) -> Tuple[jax.Array, jax.Array, jax.Array]:
    (n,) = key.shape
    assert bucket_cap & (bucket_cap - 1) == 0, bucket_cap
    valid = key != KEY_INVALID
    bid = jnp.where(valid, key // keys_per_bucket, -1).astype(jnp.int32)
    bid = jnp.minimum(bid, n_buckets - 1)       # ceil-split slack rows
    if mode == "xla":
        rank = bin_ranks_xla(bid, n_buckets=n_buckets)
    else:
        rank = bin_ranks_pallas(bid, n_buckets=n_buckets,
                                interpret=mode == "interpret")

    in_cap = jnp.logical_and(rank >= 0, rank < bucket_cap)
    dump = n_buckets * bucket_cap
    dst = jnp.where(in_cap, bid * bucket_cap + rank, dump)
    binned_key = (jnp.full((dump + 1,), KEY_INVALID, jnp.int32)
                  .at[dst].set(jnp.where(in_cap, key, KEY_INVALID))[:dump])
    binned_val = (jnp.zeros((dump + 1,), val.dtype)
                  .at[dst].set(jnp.where(in_cap, val, 0))[:dump])
    dropped = jnp.sum(jnp.logical_and(valid, jnp.logical_not(in_cap)))

    if mode == "xla":
        key_s, tot = sort_tiles_xla(binned_key, binned_val, tile=bucket_cap)
    else:
        key_s, tot = sort_tiles_pallas(binned_key, binned_val,
                                       tile=bucket_cap,
                                       interpret=mode == "interpret")
    return key_s, tot, dropped.astype(jnp.int32)
