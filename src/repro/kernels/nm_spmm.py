"""Pallas TPU kernel: N:M balanced-sparsity SpMM (gather-free, MXU path).

Y[t, :] = Σ_r  V[r, :] · X[t, M·(r // N) + O[r, :]]

``V``/``O`` are the nmSPARSE-style condensed planes of a weight whose
reduction dimension is exactly N-in-M balanced: ``V`` holds the N surviving
values of every M-wide window as dense rows (R = d_in·N/M of them) and ``O``
the within-window offsets (log2(M)-bit payload, stored int8). The balance
guarantee is what makes the kernel gather-free: instead of indexing X with
``O`` (a gather TPUs hate), each of the M possible offsets is handled as a
*masked dense matmul* —

    Y = Σ_{m < M}  X[:, windows·M + m] (repeated N×)  @  where(O == m, V, 0)

so the MXU sees M static (BT, BR) @ (BR, D) products per tile pair and the
offset planes only ever feed a vectorized compare. Per-window balance means
every condensed row carries real work: tiles are conflict-free and perfectly
load-balanced, which unstructured ELLPACK/COO paths cannot guarantee
(nmSPARSE's central observation, applied to SPLIM's structured multiply).

Grid: (t_tiles, r_tiles); the offset loop (M, small & static) is unrolled.
Output tile (BT, D) is revisited across r_tiles and accumulated in place.
BT = BR = 128 (MXU native); BR covers BR//N windows, so the X tile is
(BT, BR·M/N) — the dense columns those windows read.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitonic_merge import resolve_mode
from .ops import pad_to

BT = 128   # token tile
BR = 128   # condensed-row tile (must be a multiple of N)


def _nm_spmm_kernel(x_ref, val_ref, off_ref, o_ref, *, n: int, m: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]                          # (BT, BR·m/n) dense window cols
    val = val_ref[...]                      # (BR, D) condensed values
    off = off_ref[...].astype(jnp.int32)    # (BR, D) within-window offsets
    bt = x.shape[0]
    windows = BR // n
    xw = x.reshape(bt, windows, m)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for s in range(m):                      # static unroll over offsets
        # window column s, repeated N× to line up with condensed rows
        xs = jnp.broadcast_to(xw[:, :, s][:, :, None],
                              (bt, windows, n)).reshape(bt, BR)
        vs = jnp.where(off == s, val.astype(jnp.float32), 0.0)
        acc = acc + jnp.dot(xs, vs, preferred_element_type=jnp.float32)
    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("n", "m", "d_in", "interpret"))
def nm_spmm_pallas(x: jax.Array, val: jax.Array, off: jax.Array,
                   *, n: int, m: int, d_in: int,
                   interpret: bool = True) -> jax.Array:
    """X(t, d_in) × condensed N:M planes (R, d_out) -> (t, d_out).

    t % BT == 0, R % BR == 0 (window-aligned), handled by nm_spmm padding.
    """
    t, di = x.shape
    r, d_out = val.shape
    assert di == d_in and off.shape == val.shape
    assert t % BT == 0 and r % BR == 0 and BR % n == 0
    assert d_in == r * m // n
    bx = BR * m // n                        # dense cols one row tile reads
    grid = (t // BT, r // BR)
    kern = functools.partial(_nm_spmm_kernel, n=n, m=m)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BT, bx), lambda i, j: (i, j)),
            pl.BlockSpec((BR, d_out), lambda i, j: (j, 0)),
            pl.BlockSpec((BR, d_out), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BT, d_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d_out), x.dtype),
        interpret=interpret,
    )(x, val, off)


@functools.partial(jax.jit, static_argnames=("n", "m"))
def nm_spmm_xla(x: jax.Array, val: jax.Array, off: jax.Array,
                *, n: int, m: int) -> jax.Array:
    """XLA realization of the same masked-matmul sum (off-TPU default)."""
    t, d_in = x.shape
    r, d_out = val.shape
    windows = d_in // m
    xw = x.reshape(t, windows, m)
    off32 = off.astype(jnp.int32)
    acc = jnp.zeros((t, d_out), jnp.float32)
    for s in range(m):
        xs = jnp.broadcast_to(xw[:, :, s][:, :, None],
                              (t, windows, n)).reshape(t, r)
        vs = jnp.where(off32 == s, val.astype(jnp.float32), 0.0)
        acc = acc + jnp.dot(xs, vs, preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def nm_spmm(x: jax.Array, val: jax.Array, off: jax.Array,
            *, n: int, m: int, interpret: bool | None = None) -> jax.Array:
    """Y = X @ W for an N:M-condensed W; pads and picks the realization.

    ``interpret`` follows the repo-wide :func:`resolve_mode` convention:
    ``None`` → compiled Pallas on TPU, XLA elsewhere; ``True``/``False``
    force the interpreter / compiled Pallas (kernel tests off-TPU).
    """
    t, d_in = x.shape
    r, d_out = val.shape
    if d_in * n != r * m:
        raise ValueError(f"condensed rows {r} != d_in*N/M = {d_in}*{n}/{m}")
    mode = resolve_mode(interpret)
    if mode == "xla":
        return nm_spmm_xla(x, val, off, n=n, m=m)
    # pad tokens to BT, condensed rows to BR (window-aligned since BR % n
    # == 0 and off pads with 0 → reads padded-zero x columns, adds nothing)
    x_p = pad_to(pad_to(x, 0, BT, 0), 1, BR * m // n, 0)
    val_p = pad_to(val, 0, BR, 0)
    off_p = pad_to(off, 0, BR, 0)
    outs = []
    for lo in range(0, d_out, 512):         # chunk D like ops.ell_spmm
        y = nm_spmm_pallas(x_p, val_p[:, lo:lo + 512], off_p[:, lo:lo + 512],
                           n=n, m=m, d_in=x_p.shape[1],
                           interpret=(mode == "interpret"))
        outs.append(y)
    out = jnp.concatenate(outs, axis=-1) if len(outs) > 1 else outs[0]
    return out[:t]
