"""Pallas TPU kernel: bitonic sort + segmented sum — the in-situ-search dual.

SPLIM's accumulation repeatedly bit-serial-searches the coordinate planes for
the minimal (RI, CI), emitting equal-coordinate groups in sorted order
(paper Alg. 1 / Fig. 11). The TPU-native realization of the same contract
(DESIGN.md §2) is a bitonic compare-exchange network over packed coordinate
keys, entirely in VMEM, followed by a log-step *segmented* inclusive scan so
each run of equal keys ends with its total. Output per tile:

    key_sorted : ascending, invalid lanes parked at INT32_MAX
    val_out    : run-tail lanes carry the run total, all other lanes 0

which is exactly the paper's "sorted list of the output matrix" (Fig. 11c) —
non-tail lanes correspond to coordinates the hardware invalidated by flipping
their sign bit.

Every compare-exchange partner sits at a power-of-2 distance, so the network
needs no general gathers: partner exchange is a reshape → flip → reshape
(a lane shuffle the TPU vectorizes and XLA compiles in seconds, vs minutes
for 1-D dynamic gathers), and the whole network is O(L log² L) vectorized
select steps with the tile batch dimension riding along for free.

For product streams larger than one tile, ``sort_merge_tree_pallas`` is the
blocked realization (cf. propagation blocking in bandwidth-optimized
SpGEMM): sort all power-of-2 tiles independently (one vectorized network
over a (tiles, tile) block), then pairwise-merge sorted runs up a binary
tree. Each merge level is a single bitonic *merge network* (O(L log L), not
a full re-sort) followed by the segmented total — coalesced run-tail totals
compose across levels because non-tail lanes are already 0, so re-summing a
merged run reproduces the grand total at the new tail.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KEY_INVALID = jnp.iinfo(jnp.int32).max
_KEY_FILL = -2  # never a packed coordinate (>= 0) nor KEY_INVALID


def next_pot(x: int) -> int:
    """Smallest power of two ≥ ``x`` (≥ 1) — the network/tile width helper
    shared by the sort kernels, the streaming engine and the planner."""
    return 1 << max(0, int(x) - 1).bit_length()


def _partner(x: jax.Array, d: int) -> jax.Array:
    """x[..., lane ^ d] via reshape/flip — no gather."""
    shape = x.shape
    n = shape[-1]
    y = x.reshape(shape[:-1] + (n // (2 * d), 2, d))
    return jnp.flip(y, axis=-2).reshape(shape)


def _shift_right(x: jax.Array, d: int, fill) -> jax.Array:
    pad = jnp.full(x.shape[:-1] + (d,), fill, x.dtype)
    return jnp.concatenate([pad, x[..., :-d]], axis=-1)


def _compare_exchange(key, val, d: int, keep_min):
    """One network stage: exchange with the lane at distance ``d``.

    Equal keys are the common case here (duplicate coordinates!) — tie-break
    toward the lower lane so both values survive the exchange.
    """
    lane = jnp.arange(key.shape[-1], dtype=jnp.int32)
    is_lo = (jnp.bitwise_and(lane, d) == 0)
    pk = _partner(key, d)
    pv = _partner(val, d)
    take_self_min = jnp.logical_or(
        key < pk, jnp.logical_and(key == pk, is_lo))
    kmin = jnp.minimum(key, pk)
    kmax = jnp.maximum(key, pk)
    vmin = jnp.where(take_self_min, val, pv)
    vmax = jnp.where(take_self_min, pv, val)
    key = jnp.where(keep_min, kmin, kmax)
    val = jnp.where(keep_min, vmin, vmax)
    return key, val


def _bitonic_sort_rows(key, val):
    """Full ascending bitonic sort along the last axis (power-of-2 length)."""
    n = key.shape[-1]
    steps = int(math.log2(n))
    lane = jnp.arange(n, dtype=jnp.int32)
    for stage in range(steps):               # builds bitonic runs of 2^(s+1)
        up = (jnp.bitwise_and(lane, 1 << (stage + 1)) == 0)  # direction bit
        for sub in range(stage, -1, -1):     # merge step distance 2^sub
            d = 1 << sub
            is_lo = (jnp.bitwise_and(lane, d) == 0)
            keep_min = jnp.logical_xor(is_lo, jnp.logical_not(up))
            key, val = _compare_exchange(key, val, d, keep_min)
    return key, val


def _bitonic_merge_rows(key, val):
    """Ascending merge of *bitonic* rows: the final log₂ n stages only."""
    n = key.shape[-1]
    steps = int(math.log2(n))
    lane = jnp.arange(n, dtype=jnp.int32)
    for sub in range(steps - 1, -1, -1):
        d = 1 << sub
        keep_min = (jnp.bitwise_and(lane, d) == 0)
        key, val = _compare_exchange(key, val, d, keep_min)
    return key, val


def _segmented_total_rows(key, val):
    """Inclusive log-step segmented scan; then keep totals at run tails."""
    n = key.shape[-1]
    steps = int(math.log2(n))
    for p in range(steps):
        d = 1 << p
        gv = _shift_right(val, d, 0)
        gk = _shift_right(key, d, _KEY_FILL)
        val = val + jnp.where(gk == key, gv, 0)
    nxt_key = jnp.concatenate(
        [key[..., 1:],
         jnp.full(key.shape[:-1] + (1,), KEY_INVALID - 1, key.dtype)], axis=-1)
    is_tail = key != nxt_key
    valid = key != KEY_INVALID
    return jnp.where(jnp.logical_and(is_tail, valid), val, 0)


def merge_coalesce_pair(key_a, val_a, key_b, val_b):
    """Two-list bitonic merge: two equal-length ascending streams → one.

    Inputs follow the module's stream contract per list (ascending keys,
    KEY_INVALID padding at the tail, each valid lane carrying a total —
    coalesced lists qualify, run-tail-total streams likewise since their
    non-tail lanes are 0). Output is the merged contract over 2·L lanes:
    globally ascending keys with run-tail totals, so keys appearing in both
    inputs end with the grand total at their tail.

    Pure jnp on the bitonic machinery — usable inside a Pallas kernel *or*
    as plain XLA (the streaming engine's off-TPU merge step, where
    interpret-mode Pallas inside the slab scan would dominate wall-clock).
    O(L log L) compare-exchanges, no gathers.
    """
    key = jnp.concatenate([key_a, jnp.flip(key_b, axis=-1)], axis=-1)[None, :]
    val = jnp.concatenate([val_a, jnp.flip(val_b, axis=-1)], axis=-1)[None, :]
    key, val = _bitonic_merge_rows(key, val)
    tot = _segmented_total_rows(key, val)
    return key[0], tot[0]


def _make_sort_kernel(tile: int):
    def kernel(key_ref, val_ref, key_out_ref, val_out_ref):
        key = key_ref[...].reshape(-1, tile)
        val = val_ref[...].reshape(-1, tile)
        key, val = _bitonic_sort_rows(key, val)
        total = _segmented_total_rows(key, val)
        key_out_ref[...] = key.reshape(key_out_ref.shape)
        val_out_ref[...] = total.reshape(val_out_ref.shape)
    return kernel


def _make_merge_kernel(run: int):
    def kernel(key_ref, val_ref, key_out_ref, val_out_ref):
        key = key_ref[...].reshape(-1, 2, run)
        val = val_ref[...].reshape(-1, 2, run)
        # ascending ++ descending = bitonic, then one merge-network pass
        key = jnp.concatenate(
            [key[:, 0, :], jnp.flip(key[:, 1, :], axis=-1)], axis=-1)
        val = jnp.concatenate(
            [val[:, 0, :], jnp.flip(val[:, 1, :], axis=-1)], axis=-1)
        key, val = _bitonic_merge_rows(key, val)
        total = _segmented_total_rows(key, val)
        key_out_ref[...] = key.reshape(key_out_ref.shape)
        val_out_ref[...] = total.reshape(val_out_ref.shape)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_merge_pallas(key: jax.Array, val: jax.Array, *,
                         interpret: bool = True):
    """Sort a power-of-2-length tile of (key, val) and coalesce equal keys.

    key int32 (invalid = INT32_MAX), val float32, both 1-D of length 2^p.
    Returns (key_sorted, val_coalesced) — run tails carry totals, rest 0.
    For streams larger than one VMEM tile use ``sort_merge_tree_pallas``
    (what ops.sort_merge does).
    """
    (n,) = key.shape
    assert n & (n - 1) == 0, f"length {n} must be a power of two"
    return pl.pallas_call(
        _make_sort_kernel(n),
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), val.dtype)],
        interpret=interpret,
    )(key, val)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort_tiles_pallas(key: jax.Array, val: jax.Array, *, tile: int,
                      interpret: bool = True):
    """Independently sort+coalesce every length-``tile`` block of the stream.

    All tiles go through ONE vectorized network — the (n/tile, tile) reshape
    rides the batch axis through every compare-exchange, so trace/compile
    cost is one network regardless of tile count.
    """
    (n,) = key.shape
    assert tile & (tile - 1) == 0 and n % tile == 0, (n, tile)
    return pl.pallas_call(
        _make_sort_kernel(tile),
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), val.dtype)],
        interpret=interpret,
    )(key, val)


@functools.partial(jax.jit, static_argnames=("tile",))
def sort_tiles_xla(key: jax.Array, val: jax.Array, *, tile: int):
    """XLA realization of ``sort_tiles_pallas``'s exact output contract.

    One batched ``lax.sort`` over the (n/tile, tile) view plus the same
    segmented-total pass (pure jnp, shared with the kernels). The off-TPU
    half of the bucket/hash auto-select — on hosts without the Pallas TPU
    lowering this replaces interpret-mode Pallas (an interpreter in the hot
    accumulation path), exactly as ``fused_slab_sort_xla`` does for the
    streaming engine.
    """
    (n,) = key.shape
    assert tile & (tile - 1) == 0 and n % tile == 0, (n, tile)
    k2, v2 = jax.lax.sort((key.reshape(-1, tile), val.reshape(-1, tile)),
                          dimension=1, num_keys=1, is_stable=False)
    tot = _segmented_total_rows(k2, v2)
    return k2.reshape(n), tot.reshape(n)


def resolve_mode(interpret: bool | None) -> str:
    """Auto-select a realization for the bucket/hash accumulators.

    ``None`` (the default everywhere) → ``'pallas'`` (compiled) on TPU,
    ``'xla'`` elsewhere — never the interpreter, which is the debug path.
    Explicit ``True``/``False`` force ``'interpret'``/``'pallas'`` (kernel
    correctness tests exercise the interpreter off-TPU this way). Resolved
    in non-jitted wrappers so a backend change never hits a stale jit cache.
    """
    from .sccp_multiply import auto_interpret
    if interpret is None:
        return "xla" if auto_interpret() else "pallas"
    return "interpret" if interpret else "pallas"


@functools.partial(jax.jit, static_argnames=("run", "interpret"))
def merge_runs_pallas(key: jax.Array, val: jax.Array, *, run: int,
                      interpret: bool = True):
    """One tree level: merge adjacent sorted-coalesced runs of length ``run``
    into sorted-coalesced runs of length ``2·run`` (all pairs vectorized)."""
    (n,) = key.shape
    assert run & (run - 1) == 0 and n % (2 * run) == 0, (n, run)
    return pl.pallas_call(
        _make_merge_kernel(run),
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), val.dtype)],
        interpret=interpret,
    )(key, val)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort_merge_tree_pallas(key: jax.Array, val: jax.Array, *,
                           tile: int = 4096, interpret: bool = True):
    """Blocked sort+coalesce of an arbitrary power-of-2-length stream.

    key length must be 2^p (callers pad with KEY_INVALID / 0). Streams that
    fit one tile take the single-network path; larger streams are tile-sorted
    then pairwise-merged up the tree: log₂(n/tile) levels of O(n log run)
    compare-exchanges — O(n log² tile + n log(n/tile)·log n) total instead
    of the monolithic O(n log² n) single-tile network. Output contract
    matches ``bitonic_merge_pallas``: globally sorted keys, run-tail totals.
    """
    (n,) = key.shape
    assert n & (n - 1) == 0, f"length {n} must be a power of two"
    assert tile & (tile - 1) == 0, f"tile {tile} must be a power of two"
    if n <= tile:
        return bitonic_merge_pallas(key, val, interpret=interpret)
    key, val = sort_tiles_pallas(key, val, tile=tile, interpret=interpret)
    run = tile
    while run < n:
        key, val = merge_runs_pallas(key, val, run=run, interpret=interpret)
        run *= 2
    return key, val
