"""Pallas TPU kernel: bitonic sort + segmented sum — the in-situ-search dual.

SPLIM's accumulation repeatedly bit-serial-searches the coordinate planes for
the minimal (RI, CI), emitting equal-coordinate groups in sorted order
(paper Alg. 1 / Fig. 11). The TPU-native realization of the same contract
(DESIGN.md §2) is a bitonic compare-exchange network over packed coordinate
keys, entirely in VMEM, followed by a log-step *segmented* inclusive scan so
each run of equal keys ends with its total. Output per tile:

    key_sorted : ascending, invalid lanes parked at INT32_MAX
    val_out    : run-tail lanes carry the run total, all other lanes 0

which is exactly the paper's "sorted list of the output matrix" (Fig. 11c) —
non-tail lanes correspond to coordinates the hardware invalidated by flipping
their sign bit.

The whole network is O(L log² L) compare-exchanges on a VREG-resident tile —
each stage is one vectorized gather + select, no scalar loop, mapping the
paper's "million-row parallel search" onto 8×128 VREG lanes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KEY_INVALID = jnp.iinfo(jnp.int32).max


def _bitonic_sort_pair(key, val):
    """Full bitonic sort of a power-of-2 1-D (key, val) pair, ascending."""
    n = key.shape[0]
    steps = int(math.log2(n))
    idx = jax.lax.iota(jnp.int32, n)
    for stage in range(steps):               # builds bitonic runs of 2^(s+1)
        for sub in range(stage, -1, -1):     # merge step distance 2^sub
            d = 1 << sub
            partner = jnp.bitwise_xor(idx, d)
            pk = key[partner]
            pv = val[partner]
            up = (jnp.bitwise_and(idx, 1 << (stage + 1)) == 0)  # direction bit
            is_lo = (jnp.bitwise_and(idx, d) == 0)
            keep_min = jnp.logical_xor(is_lo, jnp.logical_not(up))
            kmin = jnp.minimum(key, pk)
            kmax = jnp.maximum(key, pk)
            # Equal keys are the common case here (duplicate coordinates!) —
            # tie-break by index so both values survive the exchange.
            take_self_min = jnp.logical_or(
                key < pk, jnp.logical_and(key == pk, idx < partner))
            vmin = jnp.where(take_self_min, val, pv)
            vmax = jnp.where(take_self_min, pv, val)
            key = jnp.where(keep_min, kmin, kmax)
            val = jnp.where(keep_min, vmin, vmax)
    return key, val


def _segmented_total(key, val):
    """Inclusive log-step segmented scan; then keep totals at run tails."""
    n = key.shape[0]
    steps = int(math.log2(n))
    idx = jax.lax.iota(jnp.int32, n)
    for p in range(steps):
        d = 1 << p
        src = idx - d
        src_ok = src >= 0
        gv = val[jnp.maximum(src, 0)]
        gk = key[jnp.maximum(src, 0)]
        same = jnp.logical_and(src_ok, gk == key)
        val = val + jnp.where(same, gv, 0)
    nxt_key = jnp.concatenate([key[1:], jnp.full((1,), KEY_INVALID - 1, key.dtype)])
    is_tail = key != nxt_key
    valid = key != KEY_INVALID
    return jnp.where(jnp.logical_and(is_tail, valid), val, 0)


def _merge_kernel(key_ref, val_ref, key_out_ref, val_out_ref):
    key = key_ref[...].reshape(-1)
    val = val_ref[...].reshape(-1)
    key, val = _bitonic_sort_pair(key, val)
    total = _segmented_total(key, val)
    key_out_ref[...] = key.reshape(key_out_ref.shape)
    val_out_ref[...] = total.reshape(val_out_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_merge_pallas(key: jax.Array, val: jax.Array, *,
                         interpret: bool = True):
    """Sort a power-of-2-length tile of (key, val) and coalesce equal keys.

    key int32 (invalid = INT32_MAX), val float32, both 1-D of length 2^p.
    Returns (key_sorted, val_coalesced) — run tails carry totals, rest 0.
    For tiles larger than one VMEM block, callers chain tiles through
    ops.sort_merge (multi-tile merge tree).
    """
    (n,) = key.shape
    assert n & (n - 1) == 0, f"length {n} must be a power of two"
    return pl.pallas_call(
        _merge_kernel,
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), val.dtype)],
        interpret=interpret,
    )(key, val)
