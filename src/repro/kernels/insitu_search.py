"""Pallas TPU kernel: the paper's Algorithm 1 — bit-serial in-situ minima
search — executed literally on bit-planes.

The ReRAM array finds all rows holding the minimal value by scanning one bit
column per step, high→low, keeping only active rows whose current bit is 0
(unless none are — then the '1' rows survive, exactly the paper's
"if no row's CB stores '1', row DRVs' activation remains the same").

On TPU the word-line parallelism maps to VREG lanes: each of the 32 steps is
one vectorized mask update over the (n,) tile in VMEM. This kernel is the
*faithful* Alg. 1 (mask of argmin rows + iterated extraction); the
production merge path (bitonic_merge.py) is its batched dual — same output
contract, one one sort instead of nnz_C scans (DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

KEY_INVALID = jnp.iinfo(jnp.int32).max


def _minima_kernel(v_ref, mask_ref):
    v = v_ref[...]
    active = v != KEY_INVALID                         # all valid rows (line 3)

    def bit_step(i, active):
        bit = 30 - i                                  # non-negative int32 keys
        zero_bit = jnp.logical_and(active,
                                   jnp.bitwise_and(v >> bit, 1) == 0)
        any_zero = jnp.any(zero_bit)
        # Alg. 1 line 8: keep '0'-bit rows iff some row had a '0' here
        return jnp.where(any_zero, zero_bit, active)

    active = jax.lax.fori_loop(0, 31, bit_step, active)
    mask_ref[...] = active


@functools.partial(jax.jit, static_argnames=("interpret",))
def minima_mask_pallas(v: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Boolean mask of the rows holding min(v). v: (n,) int32 ≥ 0;
    KEY_INVALID marks consumed/invalid rows (the flipped sign bit)."""
    (n,) = v.shape
    return pl.pallas_call(
        _minima_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(v)


def search_emit_sorted(v: jax.Array, max_unique: int,
                       *, interpret: bool = True):
    """Iterated Alg. 1 (Fig. 11): repeatedly emit the minimal value and
    invalidate its rows — produces the sorted unique values, the hardware's
    emission order. O(u · 32) scans, u = number of unique values.

    Returns (values (max_unique,), counts (max_unique,)); empty slots carry
    KEY_INVALID / 0.
    """
    def step(carry, _):
        v_cur = carry
        mask = minima_mask_pallas(v_cur, interpret=interpret)
        any_left = jnp.any(mask)
        val = jnp.min(jnp.where(mask, v_cur, KEY_INVALID))
        cnt = jnp.sum(mask)
        # flip consumed rows to invalid (the paper sets the sign bit)
        v_next = jnp.where(mask, KEY_INVALID, v_cur)
        out_val = jnp.where(any_left, val, KEY_INVALID)
        out_cnt = jnp.where(any_left, cnt, 0)
        return v_next, (out_val, out_cnt.astype(jnp.int32))

    _, (vals, counts) = jax.lax.scan(step, v, None, length=max_unique)
    return vals, counts
