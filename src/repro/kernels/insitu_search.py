"""Pallas TPU kernel: the paper's Algorithm 1 — bit-serial in-situ minima
search — executed literally on bit-planes, plus the batched emission and
coordinate-alignment primitives the ``'search'`` accumulation backend is
built from.

The ReRAM array finds all rows holding the minimal value by scanning one bit
column per step, high→low, keeping only active rows whose current bit is 0
(unless none are — then the '1' rows survive, exactly the paper's
"if no row's CB stores '1', row DRVs' activation remains the same").

On TPU the word-line parallelism maps to VREG lanes: each of the 32 steps is
one vectorized mask update over the (n,) tile in VMEM. ``_minima_kernel`` is
the *faithful* Alg. 1 (mask of argmin rows + iterated extraction);
``emit_sorted_unique`` batches its emission the way bitonic_merge batches
the full accumulation — a key-only compare-exchange network produces the
same sorted-unique key list (Fig. 11c) in one pass instead of nnz_C scans.
``align_keys`` is the second half of the paper's in-situ search: every
product coordinate is located in that sorted list by a gather-free
vectorized search (a CAM lookup on hardware; here a broadcast compare /
``searchsorted`` per realization).

Realization selection follows the repo-wide ``resolve_mode`` contract:
``interpret=None`` (the default) runs the compiled Pallas kernels on TPU and
the bit-identical XLA realization elsewhere — never the interpreter, which
explicit ``interpret=True`` reserves for kernel-correctness tests.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .bitonic_merge import _partner, next_pot, resolve_mode

KEY_INVALID = jnp.iinfo(jnp.int32).max

# Alignment kernel blocking: product lanes per grid step, structure keys
# compared per inner loop iteration (both VMEM-tile sized).
_ALIGN_TILE = 512
_ALIGN_CHUNK = 512


def _minima_kernel(v_ref, mask_ref):
    v = v_ref[...]
    active = v != KEY_INVALID                         # all valid rows (line 3)

    def bit_step(i, active):
        bit = 30 - i                                  # non-negative int32 keys
        zero_bit = jnp.logical_and(active,
                                   jnp.bitwise_and(v >> bit, 1) == 0)
        any_zero = jnp.any(zero_bit)
        # Alg. 1 line 8: keep '0'-bit rows iff some row had a '0' here
        return jnp.where(any_zero, zero_bit, active)

    active = jax.lax.fori_loop(0, 31, bit_step, active)
    mask_ref[...] = active


@functools.partial(jax.jit, static_argnames=("interpret",))
def _minima_mask_pallas_jit(v: jax.Array, *, interpret: bool) -> jax.Array:
    (n,) = v.shape
    return pl.pallas_call(
        _minima_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.bool_),
        interpret=interpret,
    )(v)


@jax.jit
def minima_mask_xla(v: jax.Array) -> jax.Array:
    """XLA realization of the bit-serial minima search's exact contract:
    boolean mask of the active rows holding min(v). The 31-step bit scan
    selects precisely the argmin rows, so one vectorized min + compare
    reproduces it bit-for-bit."""
    active = v != KEY_INVALID
    vmin = jnp.min(jnp.where(active, v, KEY_INVALID))
    return jnp.logical_and(active, v == vmin)


def minima_mask_pallas(v: jax.Array, *,
                       interpret: bool | None = None) -> jax.Array:
    """Boolean mask of the rows holding min(v). v: (n,) int32 ≥ 0;
    KEY_INVALID marks consumed/invalid rows (the flipped sign bit).
    ``interpret=None`` auto-selects: compiled Pallas on TPU, XLA off-TPU."""
    mode = resolve_mode(interpret)
    if mode == "xla":
        return minima_mask_xla(v)
    return _minima_mask_pallas_jit(v, interpret=mode == "interpret")


def search_emit_sorted(v: jax.Array, max_unique: int,
                       *, interpret: bool | None = None):
    """Iterated Alg. 1 (Fig. 11): repeatedly emit the minimal value and
    invalidate its rows — produces the sorted unique values, the hardware's
    emission order. O(u · 32) scans, u = number of unique values.

    Returns (values (max_unique,), counts (max_unique,)); empty slots carry
    KEY_INVALID / 0. The mode is resolved once, outside the scan, so the
    loop body never re-consults the backend.
    """
    mode = resolve_mode(interpret)
    if mode == "xla":
        mask_fn = minima_mask_xla
    else:
        mask_fn = functools.partial(_minima_mask_pallas_jit,
                                    interpret=mode == "interpret")

    def step(carry, _):
        v_cur = carry
        mask = mask_fn(v_cur)
        any_left = jnp.any(mask)
        val = jnp.min(jnp.where(mask, v_cur, KEY_INVALID))
        cnt = jnp.sum(mask)
        # flip consumed rows to invalid (the paper sets the sign bit)
        v_next = jnp.where(mask, KEY_INVALID, v_cur)
        out_val = jnp.where(any_left, val, KEY_INVALID)
        out_cnt = jnp.where(any_left, cnt, 0)
        return v_next, (out_val, out_cnt.astype(jnp.int32))

    _, (vals, counts) = jax.lax.scan(step, v, None, length=max_unique)
    return vals, counts


# ---------------------------------------------------------------------------
# Batched emission: the sorted-unique key list in one key-only network pass
# ---------------------------------------------------------------------------


def _sort_keys_rows(key: jax.Array) -> jax.Array:
    """Full ascending bitonic sort along the last axis — the key-only half
    of bitonic_merge's network (no value lane to carry: emission only needs
    the keys, alignment recovers each product's slot afterwards)."""
    n = key.shape[-1]
    steps = int(math.log2(n))
    lane = jnp.arange(n, dtype=jnp.int32)
    for stage in range(steps):
        up = (jnp.bitwise_and(lane, 1 << (stage + 1)) == 0)
        for sub in range(stage, -1, -1):
            d = 1 << sub
            is_lo = (jnp.bitwise_and(lane, d) == 0)
            keep_min = jnp.logical_xor(is_lo, jnp.logical_not(up))
            pk = _partner(key, d)
            key = jnp.where(keep_min, jnp.minimum(key, pk),
                            jnp.maximum(key, pk))
    return key


def _merge_keys_rows(key: jax.Array) -> jax.Array:
    """Ascending merge of *bitonic* rows: the final log₂ n stages only."""
    n = key.shape[-1]
    steps = int(math.log2(n))
    lane = jnp.arange(n, dtype=jnp.int32)
    for sub in range(steps - 1, -1, -1):
        d = 1 << sub
        keep_min = (jnp.bitwise_and(lane, d) == 0)
        pk = _partner(key, d)
        key = jnp.where(keep_min, jnp.minimum(key, pk), jnp.maximum(key, pk))
    return key


def _make_emit_sort_kernel(tile: int):
    def kernel(key_ref, out_ref):
        key = key_ref[...].reshape(-1, tile)
        out_ref[...] = _sort_keys_rows(key).reshape(out_ref.shape)
    return kernel


def _make_emit_merge_kernel(run: int):
    def kernel(key_ref, out_ref):
        key = key_ref[...].reshape(-1, 2, run)
        # ascending ++ descending = bitonic, then one merge-network pass
        key = jnp.concatenate(
            [key[:, 0, :], jnp.flip(key[:, 1, :], axis=-1)], axis=-1)
        out_ref[...] = _merge_keys_rows(key).reshape(out_ref.shape)
    return kernel


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def _emit_sort_keys_pallas(key: jax.Array, *, tile: int,
                           interpret: bool) -> jax.Array:
    """Globally sort a power-of-2 key stream: one network per VMEM tile,
    then pairwise key-only merges up the tree (bitonic_merge's blocking)."""
    (n,) = key.shape
    t = min(tile, n)
    key = pl.pallas_call(
        _make_emit_sort_kernel(t),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(key)
    run = t
    while run < n:
        key = pl.pallas_call(
            _make_emit_merge_kernel(run),
            out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
            interpret=interpret,
        )(key)
        run *= 2
    return key


def _unique_heads(ks: jax.Array, out_cap: int):
    """Run-head compaction of a sorted key stream: the first lane of every
    equal-key run, scattered densely — exactly the emission order of the
    iterated Alg. 1 scan. Returns (uk (out_cap,) ascending KEY_INVALID-
    padded, nnz = TRUE unique count, > out_cap when truncated)."""
    prev = jnp.concatenate([jnp.full((1,), -1, ks.dtype), ks[:-1]])
    head = jnp.logical_and(ks != prev, ks != KEY_INVALID)
    nnz = jnp.sum(head).astype(jnp.int32)
    dst = jnp.minimum(jnp.where(head, jnp.cumsum(head) - 1, out_cap), out_cap)
    uk = (jnp.full((out_cap + 1,), KEY_INVALID, jnp.int32)
          .at[dst].set(jnp.where(head, ks, KEY_INVALID)))[:out_cap]
    return uk, nnz


def emit_sorted_unique(key: jax.Array, out_cap: int, *,
                       interpret: bool | None = None,
                       faithful: bool = False, tile: int = 4096):
    """The ``'search'`` backend's emission phase: the sorted unique keys of
    a packed product stream — the paper's "sorted list of the output
    matrix" (Fig. 11c) that every product is subsequently aligned against.

    Returns ``(uk, nnz)``: ``uk`` (out_cap,) ascending with KEY_INVALID
    padding, ``nnz`` the true unique-key count (``nnz > out_cap`` flags
    truncation — the first ``out_cap`` unique keys are kept, matching the
    'sort' backend's truncation order).

    ``faithful=True`` runs the literal iterated Alg. 1 scan (O(out_cap·32)
    minima searches) instead of the batched key-only sort — the two are
    bit-identical; the faithful path's ``nnz`` reports ``out_cap + 1`` when
    truncated (a floor: the scan stops emitting at ``out_cap``, but any
    leftover active row still flags the overflow).
    """
    mode = resolve_mode(interpret)
    if faithful:
        if mode == "xla":
            mask_fn = minima_mask_xla
        else:
            mask_fn = functools.partial(_minima_mask_pallas_jit,
                                        interpret=mode == "interpret")

        def step(v_cur, _):
            mask = mask_fn(v_cur)
            any_left = jnp.any(mask)
            val = jnp.min(jnp.where(mask, v_cur, KEY_INVALID))
            v_next = jnp.where(mask, KEY_INVALID, v_cur)
            return v_next, jnp.where(any_left, val, KEY_INVALID)

        v_final, uk = jax.lax.scan(step, key, None, length=out_cap)
        emitted = jnp.sum(uk != KEY_INVALID).astype(jnp.int32)
        leftover = jnp.any(v_final != KEY_INVALID)
        return uk, emitted + leftover.astype(jnp.int32)
    if mode == "xla":
        ks = jnp.sort(key)
    else:
        ks = _emit_sort_keys_pallas(key, tile=tile,
                                    interpret=mode == "interpret")
    return _unique_heads(ks, out_cap)


# ---------------------------------------------------------------------------
# Alignment: locate every product key in the sorted unique list, gather-free
# ---------------------------------------------------------------------------


def _make_align_kernel(u: int, chunk: int):
    def kernel(pk_ref, uk_ref, slot_ref, hit_ref):
        pk = pk_ref[...]
        uk = uk_ref[...]

        def body(j, carry):
            slot, hit = carry
            ukc = jax.lax.dynamic_slice_in_dim(uk, j * chunk, chunk)
            # CAM-style broadcast compare: no gathers, the (tile, chunk)
            # compare matrix lives entirely in VREGs
            lt = jnp.sum((ukc[None, :] < pk[:, None]).astype(jnp.int32),
                         axis=1)
            eq = jnp.any(ukc[None, :] == pk[:, None], axis=1)
            return slot + lt, jnp.logical_or(hit, eq)

        slot0 = jnp.zeros(pk.shape, jnp.int32)
        hit0 = jnp.zeros(pk.shape, jnp.bool_)
        slot, hit = jax.lax.fori_loop(0, u // chunk, body, (slot0, hit0))
        slot_ref[...] = slot
        hit_ref[...] = hit
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def _align_keys_pallas_jit(pk: jax.Array, uk: jax.Array, *, interpret: bool):
    (n,) = pk.shape
    (u,) = uk.shape
    bt = min(_ALIGN_TILE, n)
    chunk = min(_ALIGN_CHUNK, u)
    return pl.pallas_call(
        _make_align_kernel(u, chunk),
        grid=(n // bt,),
        in_specs=[pl.BlockSpec((bt,), lambda i: (i,)),
                  pl.BlockSpec((u,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((bt,), lambda i: (i,)),
                   pl.BlockSpec((bt,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.int32),
                   jax.ShapeDtypeStruct((n,), jnp.bool_)],
        interpret=interpret,
    )(pk, uk)


@jax.jit
def align_keys_xla(pk: jax.Array, uk: jax.Array):
    """XLA realization of the alignment contract: ``searchsorted`` into the
    ascending unique keys (side='left' ⇒ slot = #{uk < pk}, identical to
    the kernel's broadcast count) plus a clipped membership probe."""
    u = uk.shape[0]
    slot = jnp.searchsorted(uk, pk, side="left").astype(jnp.int32)
    hit = jnp.take(uk, jnp.minimum(slot, u - 1), mode="clip") == pk
    return slot, hit


def align_keys(pk: jax.Array, uk: jax.Array, *,
               interpret: bool | None = None):
    """Locate every product key in the sorted unique list ``uk``.

    Returns ``(slot, hit)``: ``slot[i] = #{j : uk[j] < pk[i]}`` (the
    product's output slot when present) and ``hit[i] = pk[i] ∈ uk``. This
    is the in-situ search half of the paper's accumulation — on hardware a
    CAM lookup per product, here one vectorized gather-free pass per
    realization. KEY_INVALID padding in ``uk`` is harmless by construction
    (it is never < a valid key, and only KEY_INVALID product lanes — which
    callers mask — can equal it)."""
    mode = resolve_mode(interpret)
    if mode == "xla":
        return align_keys_xla(pk, uk)
    (n,) = pk.shape
    bt = min(_ALIGN_TILE, next_pot(max(1, n)))
    npad = (-n) % bt
    pkp = jnp.pad(pk, (0, npad), constant_values=KEY_INVALID) if npad else pk
    (u,) = uk.shape
    chunk = min(_ALIGN_CHUNK, next_pot(max(1, u)))
    upad = (-u) % chunk
    ukp = jnp.pad(uk, (0, upad), constant_values=KEY_INVALID) if upad else uk
    slot, hit = _align_keys_pallas_jit(pkp, ukp,
                                       interpret=mode == "interpret")
    return slot[:n], hit[:n]
