"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

INVALID = -1
KEY_INVALID = jnp.iinfo(jnp.int32).max


def sccp_multiply_ref(a_val, a_idx, b_val, b_idx):
    """Oracle for kernels.sccp_multiply: (k_a,n),(n,k_b) -> (k_a,n,k_b)×3."""
    val = a_val[:, :, None] * b_val[None, :, :]
    row = jnp.broadcast_to(a_idx[:, :, None], val.shape)
    col = jnp.broadcast_to(b_idx[None, :, :], val.shape)
    ok = jnp.logical_and(row >= 0, col >= 0)
    return (jnp.where(ok, val, 0),
            jnp.where(ok, row, INVALID),
            jnp.where(ok, col, INVALID))


def bitonic_merge_ref(key, val):
    """Oracle for kernels.bitonic_merge: sort keys ascending; each run of
    equal keys keeps its total at the run tail, zeros elsewhere."""
    order = jnp.argsort(key, stable=True)
    key_s = key[order]
    val_s = val[order]
    n = key.shape[0]
    same_prev = jnp.concatenate([jnp.zeros((1,), bool), key_s[1:] == key_s[:-1]])
    seg = jnp.cumsum(jnp.logical_not(same_prev)) - 1
    totals = jax.ops.segment_sum(val_s, seg, num_segments=n)
    is_tail = jnp.concatenate([key_s[1:] != key_s[:-1], jnp.ones((1,), bool)])
    valid = key_s != KEY_INVALID
    out_val = jnp.where(jnp.logical_and(is_tail, valid), totals[seg], 0)
    return key_s, out_val


def minima_mask_ref(v):
    """Oracle for kernels.insitu_search.minima_mask_pallas."""
    valid = v != KEY_INVALID
    mn = jnp.min(jnp.where(valid, v, KEY_INVALID))
    return jnp.logical_and(valid, v == mn)


def search_emit_sorted_ref(v, max_unique):
    """Oracle: sorted unique values + counts, padded with KEY_INVALID/0."""
    import numpy as np
    arr = np.asarray(v)
    arr = arr[arr != int(KEY_INVALID)]
    vals, counts = np.unique(arr, return_counts=True)
    out_v = np.full(max_unique, int(KEY_INVALID), np.int32)
    out_c = np.zeros(max_unique, np.int32)
    k = min(max_unique, len(vals))
    out_v[:k] = vals[:k]
    out_c[:k] = counts[:k]
    return out_v, out_c


def ell_spmm_ref(a_val, a_idx, x, n_rows):
    """Oracle for kernels.ell_spmm via segment_sum scatter."""
    k, n = a_val.shape
    d = x.shape[-1]
    rows = jnp.where(a_idx >= 0, a_idx, n_rows).reshape(-1)
    contrib = (a_val[:, :, None] * x[None, :, :]).reshape(-1, d)
    out = jax.ops.segment_sum(contrib, rows, num_segments=n_rows + 1)
    return out[:n_rows].astype(x.dtype)
