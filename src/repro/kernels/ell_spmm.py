"""Pallas TPU kernel: ELLPACK × dense SpMM (structured multiply, MXU path).

C[m, :] = Σ_{s,c : A.idx[s,c] == m} A.val[s,c] · X[c, :]

This is the SCCP multiply with a *structured* output (the scatter target is
the row coordinate), the workhorse behind MoE dispatch/combine and
SparseLinear (DESIGN.md §3). TPU has no scatter unit; the idiomatic mapping
is **expansion to a one-hot tile × MXU matmul** — the systolic array performs
the scatter-accumulate as a dense (BM × BN) @ (BN × D) product per tile,
which is how the hardware wants it (HW-adaptation note: a CUDA kernel would
use atomics; on TPU the one-hot matmul is the roofline-correct choice
whenever k·n/m is within ~MXU occupancy, which holds for ELLPACK widths).

Grid: (m_tiles, n_tiles); the ELLPACK slab loop (k, small & static) is
unrolled inside the kernel. Output tile (BM, D) is revisited across n_tiles
and accumulated in place (init at j == 0).

VMEM per step: a tiles 2·k·BN·4B + x tile BN·D·4B + out BM·D·4B.
BM = BN = 128 (MXU native), D ≤ 512 per call (ops.py chunks larger D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _ell_spmm_kernel(a_val_ref, a_idx_ref, x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    i = pl.program_id(0)
    row_base = i * BM
    rows = row_base + jax.lax.broadcasted_iota(jnp.int32, (BM, BN), 0)
    a_val = a_val_ref[...]            # (k, BN)
    a_idx = a_idx_ref[...]            # (k, BN)
    x = x_ref[...]                    # (BN, D)
    k = a_val.shape[0]
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for s in range(k):                # static unroll over ELLPACK slabs
        onehot = jnp.where(a_idx[s][None, :] == rows, a_val[s][None, :], 0.0)
        acc = acc + jnp.dot(onehot, x, preferred_element_type=jnp.float32)
    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret"))
def ell_spmm_pallas(a_val: jax.Array, a_idx: jax.Array, x: jax.Array,
                    *, n_rows: int, interpret: bool = True) -> jax.Array:
    """A(ELLPACK row-wise, (k, n)) @ X(n, d) -> (n_rows, d).

    n % BN == 0, n_rows % BM == 0, handled by ops.ell_spmm padding.
    """
    k, n = a_val.shape
    n2, d = x.shape
    assert n == n2 and n % BN == 0 and n_rows % BM == 0
    grid = (n_rows // BM, n // BN)
    return pl.pallas_call(
        _ell_spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((BN, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BM, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, d), x.dtype),
        interpret=interpret,
    )(a_val, a_idx, x)
