"""Fixed-capacity open-addressing hash accumulation (cf. Nagasaka et al.,
"High-performance sparse matrix-matrix products on Intel KNL").

Hash accumulators skip sorting the product stream entirely: every product
scatter-adds into a hash table keyed by its packed output coordinate, and
only the *table* (size ~ nnz(C), not ~ flops) is sorted to meet the
sorted-COO output contract. When the compression ratio flops/nnz(C) is low —
lots of distinct output coordinates, few duplicates per coordinate — the
stream-sized sort the other backends pay for buys almost no coalescing, and
probing + a table-sized bitonic pass wins.

Layout: output rows are split into ``n_blocks`` contiguous ranges; each block
owns a private power-of-two table of ``block_cap`` slots (linear probing,
multiplicative hashing). Blocks exist for the same reason propagation-blocking
buckets do — they bound the probe working set AND make the final sort
block-local: per-block tables sorted independently (all blocks ride the batch
axis of ONE bitonic network, ``bitonic_merge.sort_tiles_pallas``) concatenate
into a globally sorted stream because block key ranges are disjoint.

Slot assignment is a ``lax.while_loop`` over probe rounds (traced once — the
0.4.37 toolchain only chokes on gathers repeated across long *unrolled*
programs): each round gathers the current occupant of every pending product's
probe slot, claims empty slots with a scatter-min (ties between distinct keys
racing for one slot resolve to the min; losers probe on), and retires
products whose slot now holds their key. Values never enter the loop — once
every product knows its slot, ONE segment_sum accumulates the whole stream.

A product that exhausts ``max_probes`` (or a full block table) is dropped and
counted; callers poison ``Coo.ngroups`` with the drop count so the existing
overflow machinery reports it. By default ``max_probes = block_cap`` — linear
probing visits every slot in a full cycle, so insertion only fails when a
block's table is genuinely full. The planner sizes ``block_cap`` at ≥ 2× the
per-block nnz(C) upper bound, keeping load factor ≤ 0.5 and expected probes
O(1).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .bitonic_merge import (KEY_INVALID, resolve_mode, sort_tiles_pallas,
                            sort_tiles_xla)

_EMPTY = KEY_INVALID              # sorts-last sentinel doubles as empty slot
_HASH_MULT = np.uint32(2654435761)    # Knuth multiplicative (2^32 / phi)


def _hash(key: jax.Array, cap: int) -> jax.Array:
    """Multiplicative hash of a packed coordinate into [0, cap)."""
    h = key.astype(jnp.uint32) * _HASH_MULT
    h = h ^ (h >> np.uint32(16))
    return (h & np.uint32(cap - 1)).astype(jnp.int32)


def hash_merge(key: jax.Array, val: jax.Array, *, n_blocks: int,
               block_cap: int, keys_per_block: int,
               max_probes: Optional[int] = None,
               interpret: bool | None = None
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Hash-accumulate a packed-key product stream; emit sorted table.

    key : (n,) int32 packed row*n_cols+col, KEY_INVALID for dead lanes.
    val : (n,) float.
    Returns ``(key_sorted, totals, dropped)`` in the ``sort_merge`` output
    contract: globally sorted unique keys (block-concatenated, _EMPTY slots
    parked at each block tail) whose lanes carry full group totals, plus the
    count of products dropped by probe/table exhaustion.

    The probe loop is plain XLA everywhere; only the final table sort is a
    kernel. ``interpret=None`` (default) auto-selects its realization:
    compiled Pallas on TPU, ``sort_tiles_xla`` elsewhere — never the
    interpreter, which ``interpret=True`` still forces for kernel tests.
    """
    return _hash_merge_jit(key, val, n_blocks=n_blocks, block_cap=block_cap,
                           keys_per_block=keys_per_block,
                           max_probes=max_probes,
                           mode=resolve_mode(interpret))


@functools.partial(jax.jit, static_argnames=("n_blocks", "block_cap",
                                             "keys_per_block", "max_probes",
                                             "mode"))
def _hash_merge_jit(key: jax.Array, val: jax.Array, *, n_blocks: int,
                    block_cap: int, keys_per_block: int,
                    max_probes: Optional[int],
                    mode: str) -> Tuple[jax.Array, jax.Array, jax.Array]:
    (n,) = key.shape
    assert block_cap & (block_cap - 1) == 0, block_cap
    probes = block_cap if max_probes is None else min(max_probes, block_cap)
    tsize = n_blocks * block_cap

    valid = key != KEY_INVALID
    block = jnp.minimum(key // keys_per_block, n_blocks - 1)
    base = jnp.where(valid, block * block_cap, 0)
    h0 = _hash(key, block_cap)

    def cond(state):
        p, _, _, pending = state
        return jnp.logical_and(p < probes, jnp.any(pending))

    def body(state):
        p, table, slot_of, pending = state
        slot = base + ((h0 + p) & (block_cap - 1))
        occupant = table[slot]
        attempt = jnp.where(jnp.logical_and(pending, occupant == _EMPTY),
                            key, _EMPTY)
        table = table.at[slot].min(attempt)
        matched = jnp.logical_and(pending, table[slot] == key)
        slot_of = jnp.where(matched, slot, slot_of)
        return p + 1, table, slot_of, jnp.logical_and(
            pending, jnp.logical_not(matched))

    state = (jnp.zeros((), jnp.int32),
             jnp.full((tsize,), _EMPTY, jnp.int32),
             jnp.full((n,), -1, jnp.int32),
             valid)
    _, table_key, slot_of, pending = jax.lax.while_loop(cond, body, state)
    dropped = jnp.sum(pending)

    seg = jnp.where(slot_of >= 0, slot_of, tsize)
    table_val = jax.ops.segment_sum(jnp.where(slot_of >= 0, val, 0), seg,
                                    num_segments=tsize + 1)[:tsize]
    if mode == "xla":
        key_s, tot = sort_tiles_xla(table_key, table_val, tile=block_cap)
    else:
        key_s, tot = sort_tiles_pallas(table_key, table_val, tile=block_cap,
                                       interpret=mode == "interpret")
    return key_s, tot, dropped.astype(jnp.int32)
