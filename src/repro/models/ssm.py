"""Mamba-1 selective SSM block (falcon-mamba-7b).

TPU adaptation of the CUDA selective-scan: the fused kernel's job (keep the
(B, L, d_inner, d_state) discretized tensors out of HBM) is done here by
**chunked scanning** — a sequential ``lax.scan`` over sequence chunks whose
bodies run an associative scan in VMEM-sized working sets, with the inner
channel axis sharded over the model mesh axis. This preserves O(L) math with
an O(chunk · d_inner_local · d_state) live footprint, the same blocking
trade the GPU kernel makes in shared memory.

Decode keeps (conv window, ssm state) caches — O(1) per token, which is why
falcon-mamba runs the ``long_500k`` cell (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import maybe_shard

from .params import Spec


def _dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or math.ceil(cfg.d_model / 16)


def mamba_specs(cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm.expand * d
    st = cfg.ssm.d_state
    dt = _dt_rank(cfg)
    return {
        "w_in": Spec((d, 2 * di), ("fsdp", "ff")),
        "conv_w": Spec((cfg.ssm.d_conv, di), (None, "ff")),
        "conv_b": Spec((di,), ("ff",), init="zeros"),
        "w_x": Spec((di, dt + 2 * st), ("ff", None)),
        "w_dt": Spec((dt, di), (None, "ff")),
        "b_dt": Spec((di,), ("ff",), init="ones"),
        "a_log": Spec((di, st), ("ff", None), init="ones"),
        "d_skip": Spec((di,), ("ff",), init="ones"),
        "w_out": Spec((di, d), ("ff", "fsdp")),
    }


def _conv1d_causal(x, w, b, state=None):
    """Depthwise causal conv along seq. x: (B,S,di); w: (K,di).

    state: (B, K-1, di) trailing inputs from the previous chunk/step."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return out + b, new_state


def _ssm_scan_chunk(a_bar, bx, h0):
    """Associative scan within a chunk. a_bar/bx: (B,C,di,st); h0: (B,di,st)."""
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    a_all, h_all = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    h_all = h_all + a_all * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_apply_full(p, x, cfg, dtype,
                     conv_state=None, ssm_state=None, return_state=False):
    """Full-sequence path (train / prefill), chunked over seq."""
    b, s, d = x.shape
    di = cfg.ssm.expand * d
    st = cfg.ssm.d_state
    dtr = _dt_rank(cfg)
    chunk = min(cfg.ssm.chunk, s)
    assert s % chunk == 0, (s, chunk)

    u = x @ p["w_in"].astype(dtype)
    u = maybe_shard(u, "batch", None, "ff")
    xs, z = jnp.split(u, 2, axis=-1)

    if conv_state is None:
        conv_state = jnp.zeros((b, cfg.ssm.d_conv - 1, di), dtype)
    if ssm_state is None:
        ssm_state = jnp.zeros((b, di, st), jnp.float32)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))            # (di, st)

    def chunk_step(carry, xc):
        conv_st, h0 = carry
        xc = jnp.swapaxes(xc, 0, 1)                          # (B,C,di)
        xc, conv_st = _conv1d_causal(xc, p["conv_w"].astype(dtype),
                                     p["conv_b"].astype(dtype), conv_st)
        xc = jax.nn.silu(xc)
        proj = xc @ p["w_x"].astype(dtype)                   # (B,C,dt+2st)
        dt_r, bmat, cmat = jnp.split(proj, [dtr, dtr + st], axis=-1)
        dt_v = jax.nn.softplus(dt_r @ p["w_dt"].astype(dtype)
                               + p["b_dt"].astype(dtype)).astype(jnp.float32)
        a_bar = jnp.exp(dt_v[..., None] * a)                 # (B,C,di,st)
        bx = (dt_v * xc.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, :, None, :]
        h_all, h_last = _ssm_scan_chunk(a_bar, bx, h0)
        y = jnp.einsum("bcds,bcs->bcd", h_all, cmat.astype(jnp.float32))
        y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
        return (conv_st, h_last), jnp.swapaxes(y.astype(dtype), 0, 1)

    # layout for scan: (n_chunks, C, B, di) with xc consumed as (C,B,di)
    xs_scan = jnp.transpose(xs.reshape(b, s // chunk, chunk, di), (1, 2, 0, 3))
    (conv_state, ssm_state), ys = jax.lax.scan(
        chunk_step, (conv_state, ssm_state), xs_scan)
    y = jnp.transpose(ys, (2, 0, 1, 3)).reshape(b, s, di)
    y = y * jax.nn.silu(z)
    out = y @ p["w_out"].astype(dtype)
    if return_state:
        return out, (conv_state, ssm_state)
    return out, None


def mamba_decode(p, x, cfg, dtype, conv_state, ssm_state):
    """One-token decode. x: (B,1,d); conv_state: (B,K-1,di);
    ssm_state: (B,di,st) fp32."""
    b, _, d = x.shape
    st = cfg.ssm.d_state
    dtr = _dt_rank(cfg)
    u = x @ p["w_in"].astype(dtype)
    xs, z = jnp.split(u, 2, axis=-1)
    xs, conv_state = _conv1d_causal(xs, p["conv_w"].astype(dtype),
                                    p["conv_b"].astype(dtype), conv_state)
    xs = jax.nn.silu(xs)[:, 0]                               # (B,di)
    proj = xs @ p["w_x"].astype(dtype)
    dt_r, bmat, cmat = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt_v = jax.nn.softplus(dt_r @ p["w_dt"].astype(dtype)
                           + p["b_dt"].astype(dtype)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    a_bar = jnp.exp(dt_v[..., None] * a)                     # (B,di,st)
    bx = (dt_v * xs.astype(jnp.float32))[..., None] * bmat.astype(jnp.float32)[:, None, :]
    ssm_state = a_bar * ssm_state + bx
    y = jnp.einsum("bds,bs->bd", ssm_state, cmat.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32) * xs.astype(jnp.float32)
    y = (y.astype(dtype) * jax.nn.silu(z[:, 0]))[:, None]
    return y @ p["w_out"].astype(dtype), conv_state, ssm_state
