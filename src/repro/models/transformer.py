"""Generic decoder-only LM: composes attention/FFN/SSM/RG-LRU blocks.

An architecture is a *segment plan*: a list of (unit, repeats) where a unit
is a tuple of block kinds (e.g. recurrentgemma's ("rec","rec","local")).
Homogeneous repeats are stacked and scanned (compact HLO, fixed per-layer
memory); heterogeneous remainders unroll. The same plan drives parameter
construction, the forward/loss path, prefill, and cached decode, so every
(arch × shape) cell lowers from one code path.

Block kinds:
  attn       full-attention GQA + SwiGLU          (dense archs)
  attn_moe   GQA + SPLIM-dispatch MoE             (granite)
  mla_dense  MLA + SwiGLU                         (deepseek layer 0)
  mla_moe    MLA + MoE(+shared)                   (deepseek)
  mamba      Mamba-1 mixer only                   (falcon-mamba)
  rec        RG-LRU + SwiGLU                      (recurrentgemma)
  local      windowed GQA + SwiGLU                (recurrentgemma 1-in-3)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
from repro.parallel.sharding import maybe_shard

from . import attention as attn
from . import ffn, rglru, ssm
from .common import embed_lookup, embed_specs, next_token_loss, rmsnorm, unembed
from .params import Spec, stack

# ---------------------------------------------------------------------------
# Segment planning
# ---------------------------------------------------------------------------

def segment_plan(cfg) -> List[Tuple[Tuple[str, ...], int]]:
    L = cfg.n_layers
    if cfg.family == "ssm":
        return [(("mamba",), L)]
    if cfg.family == "hybrid":
        unit = tuple("local" if k == "attn" else k for k in cfg.griffin.pattern)
        reps, rem = divmod(L, len(unit))
        plan = [(unit, reps)]
        if rem:
            plan.append((unit[:rem], 1))
        return plan
    if cfg.moe is not None and cfg.mla is not None:
        fd = cfg.moe.first_dense_layers
        plan = []
        if fd:
            plan.append((("mla_dense",), fd))
        plan.append((("mla_moe",), L - fd))
        return plan
    if cfg.moe is not None:
        return [(("attn_moe",), L)]
    return [(("attn",), L)]


# ---------------------------------------------------------------------------
# Block specs / apply / cache
# ---------------------------------------------------------------------------

def _norm_spec(cfg):
    return Spec((cfg.d_model,), (None,), init="ones")


def block_specs(cfg, kind: str) -> Dict[str, Any]:
    s: Dict[str, Any] = {"ln1": _norm_spec(cfg)}
    if kind in ("attn", "attn_moe", "local"):
        s["attn"] = attn.gqa_specs(cfg)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = ffn.moe_specs(cfg) if kind == "attn_moe" else ffn.swiglu_specs(cfg)
    elif kind in ("mla_dense", "mla_moe"):
        s["attn"] = attn.mla_specs(cfg)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = ffn.moe_specs(cfg) if kind == "mla_moe" else ffn.swiglu_specs(cfg)
    elif kind == "mamba":
        s["mixer"] = ssm.mamba_specs(cfg)
    elif kind == "rec":
        s["rec"] = rglru.rglru_specs(cfg)
        s["ln2"] = _norm_spec(cfg)
        s["ffn"] = ffn.swiglu_specs(cfg)
    else:
        raise ValueError(kind)
    return s


def _ffn_apply(p, x, cfg, kind, dtype):
    if kind in ("attn_moe", "mla_moe"):
        return ffn.moe_apply(p, x, cfg, dtype)
    y = ffn.swiglu_apply(p, x, dtype)
    return y, jnp.zeros((), jnp.float32)


def block_apply_full(p, x, cfg, kind: str, dtype,
                     want_cache: bool, s_max: int = 0):
    """Full-seq path. Returns (x, aux_loss, cache_slice_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "attn_moe", "local"):
        window = cfg.griffin.window if kind == "local" else cfg.attn_window
        out, kv = attn.gqa_full(p["attn"], h, cfg, dtype, window=window,
                                return_kv=want_cache)
        x = x + out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, aux = _ffn_apply(p["ffn"], h2, cfg, kind, dtype)
        x = x + y
        if want_cache:
            k, v = kv
            if kind == "local":                 # ring buffer: last W slots
                w = cfg.griffin.window
                s = x.shape[1]
                if s >= w:
                    # slot layout must match decode's pos % w indexing
                    shift = s % w
                    k, v = k[:, -w:], v[:, -w:]
                    k = jnp.roll(k, shift, axis=1)
                    v = jnp.roll(v, shift, axis=1)
                    slot_pos = jnp.roll(jnp.arange(s - w, s), shift)
                else:
                    pad = w - s
                    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    slot_pos = jnp.concatenate(
                        [jnp.arange(s), jnp.full((pad,), -1, jnp.int32)])
            else:
                pad = s_max - k.shape[1]
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                # pin the cache shards at construction — otherwise the
                # per-layer stacked (L,B,S_max,kv,hd) prefill buffer
                # materializes replicated before the jit-boundary sharding
                k = maybe_shard(k, "batch", "seq_shard", None, None)
                v = maybe_shard(v, "batch", "seq_shard", None, None)
                slot_pos = jnp.where(jnp.arange(s_max) < x.shape[1],
                                     jnp.arange(s_max), -1)
            cache = {"k": k, "v": v, "slot_pos": slot_pos}
    elif kind in ("mla_dense", "mla_moe"):
        out, kv = attn.mla_full(p["attn"], h, cfg, dtype, return_kv=want_cache)
        x = x + out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, aux = _ffn_apply(p["ffn"], h2, cfg, kind, dtype)
        x = x + y
        if want_cache:
            latent, krope = kv
            pad = s_max - latent.shape[1]
            cache = {"latent": jnp.pad(latent, ((0, 0), (0, pad), (0, 0))),
                     "krope": jnp.pad(krope, ((0, 0), (0, pad), (0, 0)))}
    elif kind == "mamba":
        out, st = ssm.mamba_apply_full(p["mixer"], h, cfg, dtype,
                                       return_state=want_cache)
        x = x + out
        if want_cache:
            cache = {"conv": st[0], "ssm": st[1]}
    elif kind == "rec":
        out, st = rglru.rglru_apply_full(p["rec"], h, cfg, dtype,
                                         return_state=want_cache)
        x = x + out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], h2, cfg, kind, dtype)
        x = x + y
        if want_cache:
            cache = {"conv": st[0], "h": st[1]}
    else:
        raise ValueError(kind)
    return x, aux, cache


def block_cache_zeros(cfg, kind: str, batch: int, s_max: int, dtype):
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    if kind in ("attn", "attn_moe"):
        return {"k": jnp.zeros((batch, s_max, kv, hd), dtype),
                "v": jnp.zeros((batch, s_max, kv, hd), dtype),
                "slot_pos": jnp.full((s_max,), -1, jnp.int32)}
    if kind == "local":
        w = cfg.griffin.window
        return {"k": jnp.zeros((batch, w, kv, hd), dtype),
                "v": jnp.zeros((batch, w, kv, hd), dtype),
                "slot_pos": jnp.full((w,), -1, jnp.int32)}
    if kind in ("mla_dense", "mla_moe"):
        m = cfg.mla
        return {"latent": jnp.zeros((batch, s_max, m.kv_lora_rank), dtype),
                "krope": jnp.zeros((batch, s_max, m.rope_head_dim), dtype)}
    if kind == "mamba":
        di = cfg.ssm.expand * cfg.d_model
        return {"conv": jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype),
                "ssm": jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32)}
    if kind == "rec":
        w = rglru._width(cfg)
        return {"conv": jnp.zeros((batch, cfg.griffin.conv_width - 1, w), dtype),
                "h": jnp.zeros((batch, w), jnp.float32)}
    raise ValueError(kind)


def block_apply_decode(p, x, cfg, kind: str, dtype, cache, pos):
    """One-token path. Returns (x, new_cache)."""
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind in ("attn", "attn_moe", "local"):
        if kind == "local":
            w = cfg.griffin.window
            slot = pos % w
            out, ck, cv = attn.gqa_decode_ring(
                p["attn"], h, cfg, dtype, cache["k"], cache["v"],
                cache["slot_pos"], pos, slot, w)
            new_slot_pos = cache["slot_pos"].at[slot].set(pos)
            cache = {"k": ck, "v": cv, "slot_pos": new_slot_pos}
        else:
            out, ck, cv = attn.gqa_decode(p["attn"], h, cfg, dtype,
                                          cache["k"], cache["v"], pos)
            cache = {"k": ck, "v": cv, "slot_pos": cache["slot_pos"].at[pos].set(pos)}
        x = x + out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], h2, cfg, kind, dtype)
        x = x + y
    elif kind in ("mla_dense", "mla_moe"):
        out, cl, ckr = attn.mla_decode(p["attn"], h, cfg, dtype,
                                       cache["latent"], cache["krope"], pos)
        cache = {"latent": cl, "krope": ckr}
        x = x + out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], h2, cfg, kind, dtype)
        x = x + y
    elif kind == "mamba":
        out, conv, st = ssm.mamba_decode(p["mixer"], h, cfg, dtype,
                                         cache["conv"], cache["ssm"])
        cache = {"conv": conv, "ssm": st}
        x = x + out
    elif kind == "rec":
        out, conv, hst = rglru.rglru_decode(p["rec"], h, cfg, dtype,
                                            cache["conv"], cache["h"])
        cache = {"conv": conv, "h": hst}
        x = x + out
        h2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        y, _ = _ffn_apply(p["ffn"], h2, cfg, kind, dtype)
        x = x + y
    else:
        raise ValueError(kind)
    return x, cache


# ---------------------------------------------------------------------------
# Whole-model spec / apply
# ---------------------------------------------------------------------------

def decoder_specs(cfg) -> Dict[str, Any]:
    segs = []
    for unit, reps in segment_plan(cfg):
        unit_specs = {f"u{i}": block_specs(cfg, kind)
                      for i, kind in enumerate(unit)}
        segs.append(stack(unit_specs, reps) if reps > 1 else unit_specs)
    return {
        "embed": embed_specs(cfg),
        "segments": segs,
        "ln_f": _norm_spec(cfg),
    }


def _remat_factor(n: int):
    """Balanced (outer, inner) factoring for hierarchical remat."""
    a = int(n ** 0.5)
    while a > 1 and n % a:
        a -= 1
    return (a, n // a) if a > 1 else (1, n)


def _maybe_remat(f, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return f


def decoder_forward(params, tokens, cfg, *, prefix_embed=None,
                    want_cache: bool = False, s_max: int = 0,
                    return_hidden: bool = False):
    """Full-seq forward. tokens: (B,S) int32. prefix_embed: optional
    (B,P,d) continuous prefix (VLM patch embeddings stub).

    Returns (logits, aux_loss, cache_or_None).
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    x = embed_lookup(params["embed"], tokens, dtype)
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(dtype), x], axis=1)
    s_max = s_max or x.shape[1]
    aux_total = jnp.zeros((), jnp.float32)
    caches = []

    for seg_params, (unit, reps) in zip(params["segments"], segment_plan(cfg)):
        def seg_body(x, p_slice):
            # barrier pins per-iteration consumption of the remat-saved carry
            # so XLA cannot hoist a whole-stack fp32 convert out of the
            # backward loop (16.5 GiB/device on mistral-123b; §Perf iter 1);
            # compat wrapper keeps it differentiable on jax 0.4.x
            x = optimization_barrier(x)
            aux_seg = jnp.zeros((), jnp.float32)
            cache_u = {}
            for i, kind in enumerate(unit):
                x, aux, c = block_apply_full(p_slice[f"u{i}"], x, cfg, kind,
                                             dtype, want_cache, s_max)
                aux_seg = aux_seg + aux
                if want_cache:
                    cache_u[f"u{i}"] = c
            # Megatron-SP: residual stream sharded (batch, seq) between blocks
            x = maybe_shard(x, "batch", "seq_act", None)
            return x, (aux_seg, cache_u)

        if reps > 1:
            body = _maybe_remat(seg_body, cfg)
            outer, inner = _remat_factor(reps) if cfg.remat == "full" else (1, reps)
            if outer > 1 and not want_cache:
                # Hierarchical (√-style) remat: only outer-group carries are
                # saved across the whole backward (outer × (B,S,d) instead of
                # reps ×); inner layers re-save transiently during one
                # group's backward. Cuts the saved-stack (and XLA's hoisted
                # fp32 copy of it) by ~inner×. §Perf iteration 3.
                grouped = jax.tree.map(
                    lambda a: a.reshape((outer, inner) + a.shape[1:]), seg_params)

                # (§Perf cell C, iteration 3 — REFUTED: dropping the
                # per-layer remat inside groups cut FLOPs 16% but the inner
                # backward then saves full layer internals: temp 27→78 GiB.
                # Per-layer remat inside checkpointed groups it is.)
                @jax.checkpoint
                def group_body(xc, p_group):
                    xc, (auxs, _) = jax.lax.scan(body, xc, p_group)
                    return xc, (auxs, {})

                x, (auxs, cache_seg) = jax.lax.scan(group_body, x, grouped)
                cache_seg = None
            else:
                x, (auxs, cache_seg) = jax.lax.scan(body, x, seg_params)
            aux_total = aux_total + jnp.sum(auxs)
        else:
            body = _maybe_remat(seg_body, cfg)
            x, (aux1, cache_seg) = body(x, seg_params)
            aux_total = aux_total + aux1
        caches.append(cache_seg)

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, aux_total, (caches if want_cache else None)
    logits = unembed(params["embed"], x, dtype)
    return logits, aux_total, (caches if want_cache else None)


def decoder_loss(params, tokens, cfg, prefix_embed=None) -> jax.Array:
    """LM loss via the sequence-sharded softmax-xent (§Perf iteration 2)."""
    from .common import sharded_softmax_xent
    dtype = jnp.dtype(cfg.compute_dtype)
    hidden, aux, _ = decoder_forward(params, tokens, cfg,
                                     prefix_embed=prefix_embed,
                                     return_hidden=True)
    if prefix_embed is not None:
        hidden = hidden[:, prefix_embed.shape[1]:]
    if "out" in params["embed"]:
        w_out = params["embed"]["out"].astype(dtype)
    else:
        w_out = params["embed"]["tok"].astype(dtype).T
    loss = sharded_softmax_xent(hidden, w_out, tokens)
    return loss + 0.01 * aux


def decoder_prefill(params, tokens, cfg, s_max: int, prefix_embed=None):
    dtype = jnp.dtype(cfg.compute_dtype)
    # unembed only the final position — full-sequence prefill logits would
    # materialize (B·S, V) fp32 (22.6 GiB/device on internvl2 prefill_32k)
    hidden, _, caches = decoder_forward(params, tokens, cfg,
                                        prefix_embed=prefix_embed,
                                        want_cache=True, s_max=s_max,
                                        return_hidden=True)
    logits = unembed(params["embed"], hidden[:, -1:], dtype)
    pos = jnp.array(tokens.shape[1] + (prefix_embed.shape[1] if prefix_embed is not None else 0),
                    jnp.int32)
    return logits[:, 0], {"layers": caches, "pos": pos}


def decoder_cache_zeros(cfg, batch: int, s_max: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    caches = []
    for unit, reps in segment_plan(cfg):
        cache_u = {f"u{i}": block_cache_zeros(cfg, kind, batch, s_max, dtype)
                   for i, kind in enumerate(unit)}
        if reps > 1:
            cache_u = jax.tree.map(
                lambda c: jnp.broadcast_to(c[None], (reps,) + c.shape), cache_u)
        caches.append(cache_u)
    return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}


def decoder_decode_step(params, cache, tokens, cfg):
    """tokens: (B,1). Returns (logits (B,V), new_cache)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = embed_lookup(params["embed"], tokens, dtype)
    new_caches = []
    for seg_params, seg_cache, (unit, reps) in zip(
            params["segments"], cache["layers"], segment_plan(cfg)):
        def seg_body(x, pc):
            p_slice, c_slice = pc
            new_c = {}
            for i, kind in enumerate(unit):
                x, nc = block_apply_decode(p_slice[f"u{i}"], x, cfg, kind,
                                           dtype, c_slice[f"u{i}"], pos)
                new_c[f"u{i}"] = nc
            return x, new_c

        if reps > 1:
            x, new_seg = jax.lax.scan(seg_body, x, (seg_params, seg_cache))
        else:
            x, new_seg = seg_body(x, (seg_params, seg_cache))
        new_caches.append(new_seg)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = unembed(params["embed"], x, dtype)
    return logits[:, 0], {"layers": new_caches, "pos": pos + 1}
