"""SparseLinear — pruned weight matrices stored/applied in SPLIM formats.

DESIGN.md §3 feature 2: a magnitude-pruned weight is condensed column-wise
(the weight is the *right* operand of ``x @ W``) into ELLPACK with the
NNZ-a + σ hybrid rule; the apply path is the structured multiply
(``spmm_dense_ell`` — per-slab gather/accumulate, no decompression), with
kernels/ell_spmm.py as the Pallas tile body on TPU.

Used by the sparse-FFN option and the pruning example; the dense→sparse
conversion is a one-time host-side operation (checkpoint surgery), the
apply path is jittable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import EllCols, ell_cols_from_dense
from repro.core.spgemm import spmm_dense_ell


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero out the smallest-|w| fraction (global threshold)."""
    k = int(w.size * (1.0 - sparsity))
    if k <= 0:
        return jnp.zeros_like(w)
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0)


def sparsify_linear(w: jax.Array, sparsity: float) -> EllCols:
    """Dense (d_in, d_out) weight -> pruned column-wise ELLPACK."""
    wp = magnitude_prune(w, sparsity)
    nnz_per_row = (wp != 0).sum(axis=1)
    k = int(jnp.ceil(jnp.mean(nnz_per_row.astype(jnp.float32))
                     + jnp.std(nnz_per_row.astype(jnp.float32))))
    k = max(1, min(k, w.shape[1]))
    # hybrid rule: overflow beyond k is dropped here (fine after pruning —
    # rows above mean+σ are re-pruned to k); exact storage uses hybrid.py
    return ell_cols_from_dense(wp, k)


def sparse_linear_apply(x: jax.Array, w_ell: EllCols) -> jax.Array:
    """y = x @ W_sparse with x (..., d_in)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = spmm_dense_ell(x2, w_ell)
    return y.reshape(*lead, -1)
