"""SparseLinear — pruned weight matrices stored/applied in SPLIM formats.

DESIGN.md §3 feature 2: a magnitude-pruned weight is condensed column-wise
(the weight is the *right* operand of ``x @ W``) into ELLPACK with the
NNZ-a + σ hybrid rule; the apply path is the structured multiply
(``spmm_dense_ell`` — per-slab gather/accumulate, no decompression), with
kernels/ell_spmm.py as the Pallas tile body on TPU.

Used by the sparse-FFN option and the pruning example; the dense→sparse
conversion is a one-time host-side operation (checkpoint surgery), the
apply path is jittable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import EllCols, ell_cols_from_dense
from repro.core.nm import NmWeights, nm_from_dense
from repro.core.spgemm import spmm_dense_ell
from repro.kernels.nm_spmm import nm_spmm
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero out the smallest-|w| fraction (global threshold)."""
    k = int(w.size * (1.0 - sparsity))
    if k <= 0:
        return jnp.zeros_like(w)
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0)


def magnitude_prune_nm(w: jax.Array, n: int, m: int) -> jax.Array:
    """Keep the N largest-|w| entries of every M-window along d_in.

    The mask is *exactly* N-in-M balanced per window per column (ties break
    toward the earlier position), which is what routes the layer onto the
    gather-free kernels/nm_spmm.py fast path via core.nm.NmWeights.
    """
    d_in, d_out = w.shape
    if d_in % m:
        raise ValueError(f"d_in={d_in} not a multiple of M={m}")
    if not 0 < n <= m:
        raise ValueError(f"need 0 < N <= M, got {n}:{m}")
    aw = jnp.abs(w).reshape(d_in // m, m, d_out)
    order = jnp.argsort(-aw, axis=1, stable=True)
    rank = jnp.argsort(order, axis=1, stable=True)   # rank of each slot
    mask = (rank < n).reshape(d_in, d_out)
    return jnp.where(mask, w, 0)


def sparsify_linear(w: jax.Array, sparsity: float) -> EllCols:
    """Dense (d_in, d_out) weight -> pruned column-wise ELLPACK."""
    wp = magnitude_prune(w, sparsity)
    nnz_per_row = (wp != 0).sum(axis=1)
    k = int(jnp.ceil(jnp.mean(nnz_per_row.astype(jnp.float32))
                     + jnp.std(nnz_per_row.astype(jnp.float32))))
    k = max(1, min(k, w.shape[1]))
    # hybrid rule: overflow beyond k is dropped here (fine after pruning —
    # rows above mean+σ are re-pruned to k); exact storage uses hybrid.py
    return ell_cols_from_dense(wp, k)


def ell_from_pruned(wp: jax.Array) -> EllCols:
    """Lossless column-wise ELLPACK of an already-pruned weight.

    Unlike :func:`sparsify_linear`'s hybrid-k rule this never drops
    entries (k = widest row), so it represents exactly the same matrix as
    the N:M planes — the bit-identity contract between the fast path and
    its ELLPACK fallback rests on it.
    """
    k = max(1, int(jnp.max((wp != 0).sum(axis=1))))
    return ell_cols_from_dense(wp, k)


def sparse_linear_apply(x: jax.Array, w_ell: EllCols) -> jax.Array:
    """y = x @ W_sparse with x (..., d_in)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = spmm_dense_ell(x2, w_ell)
    return y.reshape(*lead, -1)


def nm_linear_apply(x: jax.Array, w_nm: NmWeights) -> jax.Array:
    """y = x @ W_sparse via the gather-free N:M kernel, x (..., d_in)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = nm_spmm(x2, w_nm.val, w_nm.off, n=w_nm.n, m=w_nm.m)
    return y.reshape(*lead, -1)


class SparseLinear:
    """A pruned weight layer that holds its SpGEMM structures across applies.

    The weight's sparsity pattern is frozen at construction, so every
    sparse-activation apply (``matmul_sparse``) against a recurring
    activation pattern reuses one cached :class:`SpgemmStructure` through the
    layer's ``plan.cache.StructureCache``: the first apply per activation
    pattern runs the symbolic phase, every later one is numeric-only
    (``spgemm_coo_numeric``). Pass a shared ``cache`` to pool structures
    across layers (models/ffn.SparseMLP, serve/engine do); by default the
    layer owns a small private one. Dense activations (``__call__``) take
    the usual structured SpMM and need no structure.

    ``nm`` routes the dense apply path (``plan.planner.plan_spmm_format``):

    * a tuple ``(n, m)`` prunes with :func:`magnitude_prune_nm` and stores
      the nmSPARSE condensed planes (gather-free kernels/nm_spmm.py), plus
      a *lossless* ELLPACK twin of the same matrix — bit-identical results
      on either path;
    * ``"auto"`` (default) prunes globally, then lets the planner pick the
      N:M path iff the resulting pattern happens to be balanced;
    * ``None`` forces the legacy ELLPACK-only layout.
    """

    def __init__(self, w: jax.Array, sparsity: float, *, cache=None,
                 cache_capacity: int = 16, nm="auto"):
        if isinstance(nm, tuple):
            wp = magnitude_prune_nm(w, *nm)
            shape = nm
        else:
            wp = magnitude_prune(w, sparsity)
            shape = None
            if nm == "auto":
                from repro.plan.planner import plan_spmm_format
                _, shape = plan_spmm_format(wp)
        if shape is not None:
            self.w_nm = nm_from_dense(wp, *shape)
            self.w_ell = ell_from_pruned(wp)    # bit-identical ELL twin
        else:
            self.w_nm = None
            self.w_ell = sparsify_linear(w, sparsity)
        if cache is None:
            from repro.plan.cache import StructureCache
            cache = StructureCache(capacity=cache_capacity)
        self.cache = cache

    def __call__(self, x: jax.Array) -> jax.Array:
        """Dense activations: y = x @ W_sparse (structured SpMM)."""
        fmt = "nm" if self.w_nm is not None else "ellpack"
        _obs_metrics.inc(f"sparse_linear.apply_{fmt}")
        if self.w_nm is not None:
            with _obs.span("sparse_linear.spmm", fmt="nm",
                           nm=f"{self.w_nm.n}:{self.w_nm.m}"):
                return _obs.sync(nm_linear_apply(x, self.w_nm))
        with _obs.span("sparse_linear.spmm", fmt="ellpack", k=self.w_ell.k):
            return _obs.sync(sparse_linear_apply(x, self.w_ell))

    def matmul_sparse(self, a, **spgemm_kwargs):
        """Sparse activations: C = A · W_sparse as sorted COO, two-phase.

        ``a`` is a row-wise ELLPACK activation matrix (d_batch rows,
        d_in logical columns). Symbolic work runs once per distinct A
        pattern; repeats are numeric-only. ``spgemm_kwargs`` forward to the
        structure build on a miss (``backend=``, ``out_cap=``, ...)."""
        from repro.core.spgemm import spgemm_coo_numeric
        with _obs.span("sparse_linear.matmul_sparse", k=self.w_ell.k):
            structure = self.cache.get(a, self.w_ell, **spgemm_kwargs)
            # the cache key already proved the fingerprint matches
            return spgemm_coo_numeric(a, self.w_ell, structure,
                                      validate=False)
