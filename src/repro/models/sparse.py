"""SparseLinear — pruned weight matrices stored/applied in SPLIM formats.

DESIGN.md §3 feature 2: a magnitude-pruned weight is condensed column-wise
(the weight is the *right* operand of ``x @ W``) into ELLPACK with the
NNZ-a + σ hybrid rule; the apply path is the structured multiply
(``spmm_dense_ell`` — per-slab gather/accumulate, no decompression), with
kernels/ell_spmm.py as the Pallas tile body on TPU.

Used by the sparse-FFN option and the pruning example; the dense→sparse
conversion is a one-time host-side operation (checkpoint surgery), the
apply path is jittable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.formats import EllCols, ell_cols_from_dense
from repro.core.spgemm import spmm_dense_ell
from repro.obs import trace as _obs


def magnitude_prune(w: jax.Array, sparsity: float) -> jax.Array:
    """Zero out the smallest-|w| fraction (global threshold)."""
    k = int(w.size * (1.0 - sparsity))
    if k <= 0:
        return jnp.zeros_like(w)
    thresh = jnp.sort(jnp.abs(w).reshape(-1))[-k]
    return jnp.where(jnp.abs(w) >= thresh, w, 0)


def sparsify_linear(w: jax.Array, sparsity: float) -> EllCols:
    """Dense (d_in, d_out) weight -> pruned column-wise ELLPACK."""
    wp = magnitude_prune(w, sparsity)
    nnz_per_row = (wp != 0).sum(axis=1)
    k = int(jnp.ceil(jnp.mean(nnz_per_row.astype(jnp.float32))
                     + jnp.std(nnz_per_row.astype(jnp.float32))))
    k = max(1, min(k, w.shape[1]))
    # hybrid rule: overflow beyond k is dropped here (fine after pruning —
    # rows above mean+σ are re-pruned to k); exact storage uses hybrid.py
    return ell_cols_from_dense(wp, k)


def sparse_linear_apply(x: jax.Array, w_ell: EllCols) -> jax.Array:
    """y = x @ W_sparse with x (..., d_in)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = spmm_dense_ell(x2, w_ell)
    return y.reshape(*lead, -1)


class SparseLinear:
    """A pruned weight layer that holds its SpGEMM structures across applies.

    The weight's sparsity pattern is frozen at construction, so every
    sparse-activation apply (``matmul_sparse``) against a recurring
    activation pattern reuses one cached :class:`SpgemmStructure` through the
    layer's ``plan.cache.StructureCache``: the first apply per activation
    pattern runs the symbolic phase, every later one is numeric-only
    (``spgemm_coo_numeric``). Pass a shared ``cache`` to pool structures
    across layers (models/ffn.SparseMLP, serve/engine do); by default the
    layer owns a small private one. Dense activations (``__call__``) take
    the usual structured SpMM and need no structure.
    """

    def __init__(self, w: jax.Array, sparsity: float, *, cache=None,
                 cache_capacity: int = 16):
        self.w_ell = sparsify_linear(w, sparsity)
        if cache is None:
            from repro.plan.cache import StructureCache
            cache = StructureCache(capacity=cache_capacity)
        self.cache = cache

    def __call__(self, x: jax.Array) -> jax.Array:
        """Dense activations: y = x @ W_sparse (structured SpMM)."""
        with _obs.span("sparse_linear.spmm", k=self.w_ell.k):
            return _obs.sync(sparse_linear_apply(x, self.w_ell))

    def matmul_sparse(self, a, **spgemm_kwargs):
        """Sparse activations: C = A · W_sparse as sorted COO, two-phase.

        ``a`` is a row-wise ELLPACK activation matrix (d_batch rows,
        d_in logical columns). Symbolic work runs once per distinct A
        pattern; repeats are numeric-only. ``spgemm_kwargs`` forward to the
        structure build on a miss (``backend=``, ``out_cap=``, ...)."""
        from repro.core.spgemm import spgemm_coo_numeric
        with _obs.span("sparse_linear.matmul_sparse", k=self.w_ell.k):
            structure = self.cache.get(a, self.w_ell, **spgemm_kwargs)
            # the cache key already proved the fingerprint matches
            return spgemm_coo_numeric(a, self.w_ell, structure,
                                      validate=False)
