"""RG-LRU recurrent block (recurrentgemma / Griffin).

Real-gated linear recurrent unit:  h_t = a_t ⊙ h_{t-1} + √(1−a_t²) ⊙ (i_t ⊙ x_t)
with a_t = exp(−c · softplus(Λ) ⊙ r_t), r/i input-gated sigmoids. The
recurrence is elementwise-diagonal → associative scan, chunked like the SSM.
State is just (B, width) — O(1) decode, so recurrentgemma runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import maybe_shard

from .params import Spec

_C = 8.0   # Griffin's fixed recurrence sharpness


def _width(cfg) -> int:
    return cfg.griffin.lru_width or cfg.d_model


def rglru_specs(cfg) -> dict:
    d = cfg.d_model
    w = _width(cfg)
    return {
        "w_in": Spec((d, w), ("fsdp", "ff")),
        "w_gate_branch": Spec((d, w), ("fsdp", "ff")),
        "conv_w": Spec((cfg.griffin.conv_width, w), (None, "ff")),
        "conv_b": Spec((w,), ("ff",), init="zeros"),
        "w_r": Spec((w, w), ("fsdp", "ff")),
        "w_i": Spec((w, w), ("fsdp", "ff")),
        "lam": Spec((w,), ("ff",), init="ones", scale=1.0),
        "w_out": Spec((w, d), ("ff", "fsdp")),
    }


def _conv1d_causal(x, w, b, state=None):
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b, xp[:, -(k - 1):, :]


def _lru_gates(p, x, dtype):
    r = jax.nn.sigmoid(x @ p["w_r"].astype(dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(x @ p["w_i"].astype(dtype)).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * x.astype(jnp.float32)
    return a, gated


def rglru_apply_full(p, x, cfg, dtype, conv_state=None, h0=None,
                     return_state=False, chunk: int = 512):
    """Full-sequence path. x: (B,S,d)."""
    b, s, d = x.shape
    w = _width(cfg)
    branch = jax.nn.gelu(x @ p["w_gate_branch"].astype(dtype))
    u = x @ p["w_in"].astype(dtype)
    u = maybe_shard(u, "batch", None, "ff")
    chunk = min(chunk, s)
    assert s % chunk == 0

    if conv_state is None:
        conv_state = jnp.zeros((b, cfg.griffin.conv_width - 1, w), dtype)
    if h0 is None:
        h0 = jnp.zeros((b, w), jnp.float32)

    def chunk_step(carry, uc):
        conv_st, h = carry
        uc = jnp.swapaxes(uc, 0, 1)                          # (B,C,w)
        uc, conv_st = _conv1d_causal(uc, p["conv_w"].astype(dtype),
                                     p["conv_b"].astype(dtype), conv_st)
        a, gated = _lru_gates(p, uc, dtype)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        a_all, h_all = jax.lax.associative_scan(combine, (a, gated), axis=1)
        h_all = h_all + a_all * h[:, None]
        return (conv_st, h_all[:, -1]), jnp.swapaxes(h_all.astype(dtype), 0, 1)

    u_scan = jnp.transpose(u.reshape(b, s // chunk, chunk, w), (1, 2, 0, 3))
    (conv_state, h0), ys = jax.lax.scan(chunk_step, (conv_state, h0), u_scan)
    y = jnp.transpose(ys, (2, 0, 1, 3)).reshape(b, s, w)
    out = (y * branch) @ p["w_out"].astype(dtype)
    if return_state:
        return out, (conv_state, h0)
    return out, None


def rglru_decode(p, x, cfg, dtype, conv_state, h):
    """One token. x: (B,1,d); h: (B,w) fp32."""
    branch = jax.nn.gelu(x @ p["w_gate_branch"].astype(dtype))
    u = x @ p["w_in"].astype(dtype)
    u, conv_state = _conv1d_causal(u, p["conv_w"].astype(dtype),
                                   p["conv_b"].astype(dtype), conv_state)
    a, gated = _lru_gates(p, u[:, 0], dtype)
    h = a * h + gated
    out = (h.astype(dtype)[:, None] * branch) @ p["w_out"].astype(dtype)
    return out, conv_state, h
