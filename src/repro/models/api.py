"""Model facade: one object per architecture with uniform step functions.

  model.init(rng)                      -> params (real arrays)
  model.abstract_params()              -> ShapeDtypeStruct tree (+shardings)
  model.loss(params, batch)            -> scalar (train path)
  model.prefill(params, batch)         -> (last_logits, cache)
  model.decode_step(params, cache, t)  -> (logits, cache)
  model.input_specs(shape_case)        -> batch of ShapeDtypeStructs
  model.cache_zeros(batch, s_max)      -> decode cache (or abstract specs)

``batch`` is a dict: always "tokens" (B,S); plus "frames" (audio stub) or
"patches" (VLM stub) for the modality archs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCase

from . import encdec, transformer
from .params import abstract_params, count_params, init_params


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def specs(self):
        if self.cfg.family == "audio":
            return encdec.encdec_specs(self.cfg)
        return transformer.decoder_specs(self.cfg)

    def init(self, rng) -> Any:
        return init_params(self.specs(), rng, jnp.dtype(self.cfg.param_dtype))

    def abstract_params(self):
        return abstract_params(self.specs(), jnp.dtype(self.cfg.param_dtype))

    def n_params(self) -> int:
        return count_params(self.specs())

    # -- steps ---------------------------------------------------------------
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_loss(params, batch["frames"], batch["tokens"], cfg)
        prefix = batch.get("patches") if cfg.family == "vlm" else None
        return transformer.decoder_loss(params, batch["tokens"], cfg,
                                        prefix_embed=prefix)

    def prefill(self, params, batch, s_max: int):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_prefill(params, batch["frames"],
                                         batch["tokens"], cfg, s_max)
        prefix = batch.get("patches") if cfg.family == "vlm" else None
        return transformer.decoder_prefill(params, batch["tokens"], cfg,
                                           s_max, prefix_embed=prefix)

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_decode_step(params, cache, tokens, cfg)
        return transformer.decoder_decode_step(params, cache, tokens, cfg)

    def cache_zeros(self, batch: int, s_max: int):
        cfg = self.cfg
        if cfg.family == "audio":
            return encdec.encdec_cache_zeros(cfg, batch, s_max)
        return transformer.decoder_cache_zeros(cfg, batch, s_max)

    # -- dry-run inputs -------------------------------------------------------
    def input_specs(self, case: ShapeCase) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for one assigned shape cell.

        For decode cells the "tokens" spec is the one-step (B, 1) batch; the
        cache is produced separately by cache_zeros / abstract eval.
        """
        cfg = self.cfg
        b, s = case.global_batch, case.seq_len
        if case.kind == "decode":
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
            return specs
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.compute_dtype))
            # decoder consumes the assigned seq_len as its token stream
        if cfg.family == "vlm" and cfg.n_vision_tokens:
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
            # text seq shrinks so total positions == assigned seq_len
            specs["tokens"] = jax.ShapeDtypeStruct(
                (b, s - cfg.n_vision_tokens), jnp.int32)
        return specs


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
