"""Whisper-style encoder-decoder (whisper-medium backbone).

The audio frontend (log-mel + 2×conv) is a STUB per the assignment:
``input_specs()`` supplies precomputed frame embeddings (B, 1500, d_model).
Encoder: bidirectional self-attention + GELU MLP. Decoder: causal
self-attention + cross-attention over encoder output + GELU MLP. Pre-LN
LayerNorm (with bias), MHA (n_kv_heads == n_heads), sinusoidal positions
(deviation from whisper's learned decoder positions, noted in DESIGN.md —
keeps position tables independent of the assigned 32k shape cells).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.parallel.sharding import maybe_shard

from . import attention as attn
from .common import layernorm, sinusoidal_positions
from .ffn import gelu_mlp_apply, gelu_mlp_specs
from .params import Spec, stack


def _ln_spec(cfg):
    return {"w": Spec((cfg.d_model,), (None,), init="ones"),
            "b": Spec((cfg.d_model,), (None,), init="zeros")}


def _enc_layer_specs(cfg):
    return {"ln1": _ln_spec(cfg), "attn": attn.gqa_specs(cfg),
            "ln2": _ln_spec(cfg), "mlp": gelu_mlp_specs(cfg)}


def _dec_layer_specs(cfg):
    return {"ln1": _ln_spec(cfg), "self": attn.gqa_specs(cfg),
            "ln2": _ln_spec(cfg), "cross": attn.cross_specs(cfg),
            "ln3": _ln_spec(cfg), "mlp": gelu_mlp_specs(cfg)}


def encdec_specs(cfg) -> Dict[str, Any]:
    return {
        "embed": {"tok": Spec((cfg.vocab, cfg.d_model), ("vocab", "fsdp"),
                              scale=cfg.d_model ** -0.5)},
        "encoder": stack(_enc_layer_specs(cfg), cfg.n_encoder_layers),
        "enc_ln": _ln_spec(cfg),
        "decoder": stack(_dec_layer_specs(cfg), cfg.n_layers),
        "dec_ln": _ln_spec(cfg),
    }


def _ln(x, p, eps):
    return layernorm(x, p["w"], p["b"], eps)


def encode(params, frames, cfg):
    """frames: (B, T_enc, d) stub frontend output -> encoder states."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dtype) + sinusoidal_positions(
        frames.shape[1], cfg.d_model).astype(dtype)[None]
    x = maybe_shard(x, "batch", None, None)

    def body(x, p):
        h = _ln(x, p["ln1"], cfg.norm_eps)
        out, _ = attn.gqa_full(p["attn"], h, cfg, dtype, causal=False)
        x = x + out
        h = _ln(x, p["ln2"], cfg.norm_eps)
        x = x + gelu_mlp_apply(p["mlp"], h, dtype)
        return x, ()

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return _ln(x, params["enc_ln"], cfg.norm_eps)


def decode_full(params, tokens, enc_out, cfg, want_cache=False, s_max=0,
                return_hidden=False):
    """Teacher-forced decoder pass. Returns (logits_or_hidden, cache)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    s = tokens.shape[1]
    s_max = s_max or s
    x = params["embed"]["tok"].astype(dtype)[tokens]
    x = x + sinusoidal_positions(s, cfg.d_model).astype(dtype)[None]
    x = maybe_shard(x, "batch", None, None)

    def body(x, p):
        h = _ln(x, p["ln1"], cfg.norm_eps)
        out, kv = attn.gqa_full(p["self"], h, cfg, dtype, return_kv=want_cache)
        x = x + out
        h = _ln(x, p["ln2"], cfg.norm_eps)
        ck, cv = attn.cross_kv(p["cross"], enc_out, cfg, dtype)
        x = x + attn.cross_apply(p["cross"], h, ck, cv, cfg, dtype)
        h = _ln(x, p["ln3"], cfg.norm_eps)
        x = x + gelu_mlp_apply(p["mlp"], h, dtype)
        cache = None
        if want_cache:
            k, v = kv
            pad = s_max - k.shape[1]
            cache = {"k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                     "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                     "ck": ck, "cv": cv}
        return x, cache

    if cfg.remat == "full" and not want_cache:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, params["decoder"])
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    if return_hidden:
        return x, caches
    logits = x @ params["embed"]["tok"].astype(dtype).T
    logits = maybe_shard(logits, "batch", None, "vocab")
    return logits, caches


def encdec_loss(params, frames, tokens, cfg):
    """Sequence-sharded loss (whisper's 51,865 vocab does not divide the
    model axis, so vocab sharding drops and naive full logits cost 3 ×
    12.7 GiB fp32 per device — §Perf follow-up D.1)."""
    from .common import sharded_softmax_xent
    dtype = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(params, frames, cfg)
    hidden, _ = decode_full(params, tokens, enc_out, cfg, return_hidden=True)
    w_out = params["embed"]["tok"].astype(dtype).T
    return sharded_softmax_xent(hidden, w_out, tokens)


def encdec_prefill(params, frames, tokens, cfg, s_max: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    enc_out = encode(params, frames, cfg)
    hidden, caches = decode_full(params, tokens, enc_out, cfg,
                                 want_cache=True, s_max=s_max,
                                 return_hidden=True)
    logits = hidden[:, -1:] @ params["embed"]["tok"].astype(dtype).T
    return logits[:, 0], {"layers": caches,
                          "pos": jnp.array(tokens.shape[1], jnp.int32)}


def encdec_cache_zeros(cfg, batch: int, s_max: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    hd, h = cfg.head_dim, cfg.n_heads
    L = cfg.n_layers
    t_enc = cfg.encoder_seq
    return {"layers": {
        "k": jnp.zeros((L, batch, s_max, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, s_max, cfg.n_kv_heads, hd), dtype),
        "ck": jnp.zeros((L, batch, t_enc, h, hd), dtype),
        "cv": jnp.zeros((L, batch, t_enc, h, hd), dtype)},
        "pos": jnp.zeros((), jnp.int32)}


def encdec_decode_step(params, cache, tokens, cfg):
    """tokens: (B,1). Cross-KV comes from the prefill cache."""
    dtype = jnp.dtype(cfg.compute_dtype)
    pos = cache["pos"]
    x = params["embed"]["tok"].astype(dtype)[tokens]
    posv = pos[None]
    # sinusoidal position of the current step
    d = cfg.d_model
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = posv.astype(jnp.float32)[:, None] / (10000.0 ** (2 * dim / d))
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
    x = x + pe[None]

    def body(x, pc):
        p, c = pc
        h = _ln(x, p["ln1"], cfg.norm_eps)
        out, ck_new, cv_new = attn.gqa_decode(p["self"], h, cfg, dtype,
                                              c["k"], c["v"], pos)
        x = x + out
        h = _ln(x, p["ln2"], cfg.norm_eps)
        x = x + attn.cross_apply(p["cross"], h, c["ck"], c["cv"], cfg, dtype)
        h = _ln(x, p["ln3"], cfg.norm_eps)
        x = x + gelu_mlp_apply(p["mlp"], h, dtype)
        return x, {"k": ck_new, "v": cv_new, "ck": c["ck"], "cv": c["cv"]}

    x, new_layers = jax.lax.scan(body, x, (params["decoder"], cache["layers"]))
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    logits = x @ params["embed"]["tok"].astype(dtype).T
    return logits[:, 0], {"layers": new_layers, "pos": pos + 1}
