"""Parameter spec system — one source of truth for shapes, init, sharding.

Modules declare parameters as ``Spec`` leaves in nested dicts. From the same
tree we derive: real initialized params (smoke tests / training), abstract
``ShapeDtypeStruct`` params (the dry-run's no-allocation path), and
``NamedSharding``s via the logical-axis rules (parallel/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import current_rules

Tree = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axes, len == len(shape)
    init: str = "normal"                     # normal | zeros | ones
    scale: Optional[float] = None            # None -> 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def stack(spec_tree: Tree, n: int, axis_name: Optional[str] = None) -> Tree:
    """Prepend a layer-stack dimension to every Spec (for scan-over-layers)."""
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        spec_tree, is_leaf=is_spec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    return int(np.prod(shape[:-1]))


def init_params(spec_tree: Tree, rng: jax.Array, dtype) -> Tree:
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    rngs = jax.random.split(rng, len(leaves))
    outs = []
    for spec, r in zip(leaves, rngs):
        if spec.init == "zeros":
            outs.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            outs.append(jnp.ones(spec.shape, dtype))
        else:
            scale = spec.scale if spec.scale is not None else _fan_in(spec.shape) ** -0.5
            outs.append((jax.random.normal(r, spec.shape, jnp.float32) * scale).astype(dtype))
    return jax.tree.unflatten(treedef, outs)


def abstract_params(spec_tree: Tree, dtype) -> Tree:
    """ShapeDtypeStruct tree with shardings attached — zero allocation."""
    def mk(spec: Spec):
        rules = current_rules()
        sharding = None
        if rules is not None and rules.mesh is not None:
            from jax.sharding import NamedSharding
            sharding = NamedSharding(rules.mesh, rules.resolve(spec.axes, spec.shape))
        return jax.ShapeDtypeStruct(spec.shape, dtype, sharding=sharding)
    return jax.tree.map(mk, spec_tree, is_leaf=is_spec)


def param_shardings(spec_tree: Tree):
    """NamedSharding tree (requires an active sharding_rules context)."""
    rules = current_rules()
    assert rules is not None and rules.mesh is not None
    from jax.sharding import NamedSharding

    def mk(spec: Spec):
        return NamedSharding(rules.mesh, rules.resolve(spec.axes, spec.shape))
    return jax.tree.map(mk, spec_tree, is_leaf=is_spec)


def count_params(spec_tree: Tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return sum(int(np.prod(s.shape)) for s in leaves)
