"""Attention variants: GQA/MHA (+bias), sliding-window, MLA, cross-attention.

All variants expose three paths sharing the same parameters:
  * full-sequence (train / prefill)   — causal or windowed mask
  * decode                            — one query token against a KV cache
Prefill fills the cache in the same pass.

Sharding: head-structured tensors are annotated with the "heads"/"kv_heads"
logical axes (tensor parallel); the decode path additionally annotates the
cache sequence axis with "seq_shard" so long caches shard over the model
axis when heads don't divide it (flash-decode style — XLA inserts the
partial-softmax all-reduce over the sharded seq reductions).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import maybe_shard

from .common import apply_rope, rmsnorm, rope_angles
from .params import Spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA / MHA
# ---------------------------------------------------------------------------

def gqa_specs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    s = {
        "wq": Spec((d, h * hd), ("fsdp", "qkv_flat")),
        "wk": Spec((d, kv * hd), ("fsdp", "qkv_flat")),
        "wv": Spec((d, kv * hd), ("fsdp", "qkv_flat")),
        "wo": Spec((h * hd, d), ("qkv_flat", "fsdp")),
    }
    if cfg.qkv_bias:
        s["bq"] = Spec((h * hd,), ("qkv_flat",), init="zeros")
        s["bk"] = Spec((kv * hd,), ("qkv_flat",), init="zeros")
        s["bv"] = Spec((kv * hd,), ("qkv_flat",), init="zeros")
    return s


def _project_qkv(p, x, cfg, dtype):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = maybe_shard(q.reshape(b, s, h, hd), "batch", None, "heads", None)
    k = maybe_shard(k.reshape(b, s, kv, hd), "batch", None, "kv_heads", None)
    v = maybe_shard(v.reshape(b, s, kv, hd), "batch", None, "kv_heads", None)
    return q, k, v


def _sdpa(q, k, v, mask, n_kv: int) -> jax.Array:
    """q: (B,S,H,hd), k/v: (B,T,KV,hd), mask: (S,T) or (B,S,T) bool."""
    b, s, h, hd = q.shape
    t = k.shape[1]
    g = h // n_kv
    q = q.reshape(b, s, n_kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    if mask is not None:
        if mask.ndim == 2:
            mask_b = mask[None, None, None]
        else:
            mask_b = mask[:, None, None]
        scores = jnp.where(mask_b, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(b, s, h, v.shape[-1])


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is ≤ target (whisper's 1500 → 500)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def _sdpa_chunked(q, k, v, n_kv: int, causal: bool, window: int,
                  chunk_q: int = 512, chunk_k: int = 512) -> jax.Array:
    """Flash-style online-softmax attention: double scan over (Q, K) blocks.

    Never materializes the (S, T) score matrix — the live working set is one
    (B, KV, g, Cq, Ck) tile plus running (max, denom, acc) statistics, the
    VMEM-blocking structure a fused TPU kernel would use. Backward recomputes
    the inner body (jax.checkpoint) — standard flash remat.
    """
    b, s, h, hd = q.shape
    t = k.shape[1]
    hv = v.shape[-1]                 # may differ from hd (MLA: 192 vs 128)
    g = h // n_kv
    cq = _pick_chunk(s, chunk_q)
    ck = _pick_chunk(t, chunk_k)
    assert s % cq == 0 and t % ck == 0, (s, cq, t, ck)
    scale = hd ** -0.5
    qb = q.reshape(b, s // cq, cq, n_kv, g, hd)
    kb = k.reshape(b, t // ck, ck, n_kv, hd)
    vb = v.reshape(b, t // ck, ck, n_kv, hv)

    def q_block(qi, q_tile):
        # q_tile: (B, Cq, KV, g, hd)
        q_pos = qi * cq + jnp.arange(cq)

        @jax.checkpoint
        def kv_step(carry, inp):
            m, l, acc = carry
            ki, k_tile, v_tile = inp
            k_pos = ki * ck + jnp.arange(ck)
            s_blk = jnp.einsum("bqkgh,btkh->bkgqt", q_tile, k_tile)
            s_blk = (s_blk * scale).astype(jnp.float32)
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
            if window:
                mask = jnp.logical_and(mask, q_pos[:, None] - k_pos[None, :] < window)
            s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, s_blk.max(-1))
            p_blk = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p_blk.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p_blk.astype(v_tile.dtype), v_tile).astype(jnp.float32)
            return (m_new, l, acc), ()

        init = (jnp.full((b, n_kv, g, cq), NEG_INF, jnp.float32),
                jnp.zeros((b, n_kv, g, cq), jnp.float32),
                jnp.zeros((b, n_kv, g, cq, hv), jnp.float32))
        ks = jnp.arange(t // ck)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init, (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4))           # (B,Cq,KV,g,hd)

    # checkpoint each q block: its inner KV-scan statistics (m, l, acc) are
    # recomputed in the backward instead of being saved across every
    # (q block × kv step) pair — 1.5 GiB/device/layer otherwise.
    outs = jax.lax.map(jax.checkpoint(lambda args: q_block(*args)),
                       (jnp.arange(s // cq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hv)
    return out.astype(q.dtype)


CHUNKED_THRESHOLD = 1024


def causal_mask(s: int, window: int = 0) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window:
        m = jnp.logical_and(m, i - j < window)
    return m


def gqa_full(p, x, cfg, dtype, window: int = 0, causal: bool = True,
             return_kv: bool = False):
    """Train / prefill path. Returns (out, (k, v)).

    KV heads are broadcast up to the full head count before the score
    computation: a (KV, group) split of the head axis is un-shardable when
    n_kv_heads < the model-axis size, whereas the repeated (B,S,H,hd) layout
    shards cleanly on "heads" (the repeat is a local broadcast, no extra
    FLOPs in the einsum). The cache keeps the compact KV-head layout.
    """
    from repro.parallel.sharding import axis_size
    s = x.shape[1]
    q, k, v = _project_qkv(p, x, cfg, dtype)
    pos = jnp.arange(s)
    cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kv_compact = (k, v)
    # (§Perf cell C, iteration 2 — REFUTED: a sequence-sharded flash variant
    # [q seq-sharded, compact KV replicated] raised HLO FLOPs +63% and HBM
    # bytes 4× under the SPMD partitioner; head-sharded with KV repeat wins.)
    g = cfg.n_heads // cfg.n_kv_heads
    if g > 1:
        k = maybe_shard(jnp.repeat(k, g, axis=2), "batch", None, "heads", None)
        v = maybe_shard(jnp.repeat(v, g, axis=2), "batch", None, "heads", None)
    n_kv = cfg.n_heads
    if s > CHUNKED_THRESHOLD:
        out = _sdpa_chunked(q, k, v, n_kv, causal, window)
    else:
        mask = causal_mask(s, window) if causal else None
        out = _sdpa(q, k, v, mask, n_kv)
    out = out.reshape(*x.shape[:2], -1) @ p["wo"].astype(dtype)
    # Megatron-SP epilogue: when attention is genuinely head-sharded, pin the
    # wo partial-sum output back to (batch, seq) sharding (§Perf cell C,
    # iter 1: −2 GiB temp on mistral). When heads do NOT divide the model
    # axis (granite's 24, yi's 56) the pin makes GSPMD re-partition the
    # replicated attention — +2.5× FLOPs measured on granite — so fall back.
    if cfg.n_heads % max(1, axis_size("heads")) == 0:
        out = maybe_shard(out, "batch", "seq_act", None)
    else:
        out = maybe_shard(out, "batch", None, None)
    return (out, kv_compact) if return_kv else (out, None)


def gqa_decode(p, x, cfg, dtype, cache_k, cache_v, pos, window: int = 0):
    """One-token decode. cache_k/v: (B, S_max, KV, hd); pos: scalar int32.

    Returns (out, new_cache_k, new_cache_v).
    """
    b = x.shape[0]
    s_max = cache_k.shape[1]
    q, k, v = _project_qkv(p, x, cfg, dtype)          # S = 1
    posv = pos[None] if pos.ndim == 0 else pos
    cos, sin = rope_angles(posv, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, pos, axis=1)
    cache_k = maybe_shard(cache_k, "batch", "seq_shard", None, None)
    cache_v = maybe_shard(cache_v, "batch", "seq_shard", None, None)
    t_idx = jnp.arange(s_max)
    mask = t_idx <= pos
    if window:
        mask = jnp.logical_and(mask, t_idx > pos - window)
    out = _sdpa(q, cache_k, cache_v, mask[None, :], cfg.n_kv_heads)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(dtype)
    return out, cache_k, cache_v


def gqa_decode_ring(p, x, cfg, dtype, cache_k, cache_v, slot_pos, pos,
                    slot, window: int):
    """Sliding-window decode against a ring-buffer cache of W slots.

    cache_k/v: (B, W, KV, hd); slot_pos: (W,) absolute position stored in
    each slot (-1 = empty). Keys carry RoPE at their absolute positions, so
    scores stay correct regardless of ring layout. This is what makes
    recurrentgemma's long_500k cell O(W) instead of O(S).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, cfg, dtype)
    posv = pos[None] if pos.ndim == 0 else pos
    cos, sin = rope_angles(posv, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    new_slot_pos = slot_pos.at[slot].set(pos)
    mask = jnp.logical_and(new_slot_pos >= 0, new_slot_pos > pos - window)
    out = _sdpa(q, cache_k, cache_v, mask[None, :], cfg.n_kv_heads)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(dtype)
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank latent KV, absorbed decode
# ---------------------------------------------------------------------------

def mla_specs(cfg) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    return {
        "wq": Spec((d, h * qd), ("fsdp", "qkv_flat")),
        "w_dkv": Spec((d, m.kv_lora_rank + m.rope_head_dim), ("fsdp", None)),
        "kv_norm": Spec((m.kv_lora_rank,), (None,), init="ones"),
        "w_uk": Spec((m.kv_lora_rank, h, m.nope_head_dim), (None, "heads", None)),
        "w_uv": Spec((m.kv_lora_rank, h, m.v_head_dim), (None, "heads", None)),
        "wo": Spec((h * m.v_head_dim, d), ("qkv_flat", "fsdp")),
    }


def _mla_q(p, x, cfg, dtype, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    q = (x @ p["wq"].astype(dtype)).reshape(b, s, h, qd)
    q = maybe_shard(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    cos, sin = rope_angles(positions, m.rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_latent(p, x, cfg, dtype, positions):
    m = cfg.mla
    ckv = x @ p["w_dkv"].astype(dtype)
    latent = rmsnorm(ckv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv[..., m.kv_lora_rank:][:, :, None, :]    # (B,S,1,rope_d)
    cos, sin = rope_angles(positions, m.rope_head_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0, :]
    return latent, k_rope


def mla_full(p, x, cfg, dtype, return_kv: bool = False):
    """Train / prefill: materialize per-head K/V from the latent."""
    m = cfg.mla
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q_nope, q_rope = _mla_q(p, x, cfg, dtype, positions)
    latent, k_rope = _mla_latent(p, x, cfg, dtype, positions)
    k_nope = jnp.einsum("bsl,lhn->bshn", latent, p["w_uk"].astype(dtype))
    v = jnp.einsum("bsl,lhv->bshv", latent, p["w_uv"].astype(dtype))
    # fold the decoupled-rope score split into one concat-head attention:
    # score = q_nope·k_nope + q_rope·k_rope  (k_rope shared across heads)
    h = cfg.n_heads
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], h, m.rope_head_dim))],
        axis=-1)
    # K/V inherit the latent's *seq* sharding while Q is *head*-sharded; the
    # mismatch makes GSPMD re-gather fp32 flash tiles per (q,kv) block pair
    # (~1.6 GiB × blocks × layers on deepseek). One bf16 gather per layer
    # here instead. §Perf cell B, iteration 6.
    kc = maybe_shard(kc, "batch", None, "heads", None)
    v = maybe_shard(v, "batch", None, "heads", None)
    if s > CHUNKED_THRESHOLD:
        out = _sdpa_chunked(qc, kc, v, h, causal=True, window=0)
    else:
        out = _sdpa(qc, kc, v, causal_mask(s), h)
    out = out.reshape(b, s, -1) @ p["wo"].astype(dtype)
    return (out, (latent, k_rope)) if return_kv else (out, None)


def mla_decode(p, x, cfg, dtype, cache_latent, cache_krope, pos):
    """Absorbed decode: score directly in latent space (B,T,kv_lora cache).

    cache_latent: (B, S_max, kv_lora); cache_krope: (B, S_max, rope_d).
    """
    m = cfg.mla
    b = x.shape[0]
    posv = pos[None] if pos.ndim == 0 else pos
    q_nope, q_rope = _mla_q(p, x, cfg, dtype, posv)
    latent_t, krope_t = _mla_latent(p, x, cfg, dtype, posv)
    cache_latent = jax.lax.dynamic_update_slice_in_dim(cache_latent, latent_t, pos, axis=1)
    cache_krope = jax.lax.dynamic_update_slice_in_dim(cache_krope, krope_t, pos, axis=1)
    cache_latent = maybe_shard(cache_latent, "batch", "seq_shard", None)
    cache_krope = maybe_shard(cache_krope, "batch", "seq_shard", None)
    # absorb W_uk into q: q' (B,1,H,L)
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, p["w_uk"].astype(dtype))
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, cache_latent)
              + jnp.einsum("bshr,btr->bhst", q_rope, cache_krope)).astype(jnp.float32)
    scores = scores * scale
    t_idx = jnp.arange(cache_latent.shape[1])
    scores = jnp.where((t_idx <= pos)[None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(dtype)
    ctx = jnp.einsum("bhst,btl->bshl", w, cache_latent)    # (B,1,H,L)
    out = jnp.einsum("bshl,lhv->bshv", ctx, p["w_uv"].astype(dtype))
    out = out.reshape(b, 1, -1) @ p["wo"].astype(dtype)
    return out, cache_latent, cache_krope


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_specs(cfg) -> dict:
    d, hd, h = cfg.d_model, cfg.head_dim, cfg.n_heads
    return {
        "wq": Spec((d, h * hd), ("fsdp", "qkv_flat")),
        "wk": Spec((d, h * hd), (None, "qkv_flat")),
        "wv": Spec((d, h * hd), (None, "qkv_flat")),
        "wo": Spec((h * hd, d), ("qkv_flat", "fsdp")),
    }


def cross_kv(p, enc_out, cfg, dtype):
    b, t, _ = enc_out.shape
    h, hd = cfg.n_heads, cfg.head_dim
    k = (enc_out @ p["wk"].astype(dtype)).reshape(b, t, h, hd)
    v = (enc_out @ p["wv"].astype(dtype)).reshape(b, t, h, hd)
    return k, v


def cross_apply(p, x, k, v, cfg, dtype):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"].astype(dtype)).reshape(b, s, h, hd)
    out = _sdpa(q, k, v, None, h)
    return out.reshape(b, s, -1) @ p["wo"].astype(dtype)
