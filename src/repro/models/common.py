"""Shared layers: norms, RoPE, embeddings, dense projections."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import maybe_shard

from .params import Spec


def rmsnorm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with the variance reduction in fp32 but the scale multiply in
    the compute dtype: the fp32 convert of ``x`` feeds only the reduction, so
    XLA fuses it instead of materializing (and hoisting!) a full-width fp32
    copy of the residual stream — see EXPERIMENTS.md §Perf iteration 1."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., head_dim/2), fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- embeddings -------------------------------------------------------------

def embed_specs(cfg) -> dict:
    s = {"tok": Spec((cfg.vocab, cfg.d_model), ("vocab", "fsdp"),
                     scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        s["out"] = Spec((cfg.d_model, cfg.vocab), ("fsdp", "vocab"))
    return s


def embed_lookup(params: dict, tokens: jax.Array, compute_dtype) -> jax.Array:
    # Shard token ids over (batch, seq) BEFORE the table gather so the
    # (B,S,d) output (and its backward scatter) is born sequence-sharded —
    # otherwise the gather materializes the full-sequence residual and its
    # fp32 cotangent per device. §Perf iteration 4.
    tokens = maybe_shard(tokens, "batch", "seq_act")
    x = params["tok"].astype(compute_dtype)[tokens]
    return maybe_shard(x, "batch", "seq_act", None)


def unembed(params: dict, x: jax.Array, compute_dtype) -> jax.Array:
    if "out" in params:
        w = params["out"].astype(compute_dtype)
    else:
        w = params["tok"].astype(compute_dtype).T
    logits = x @ w
    return maybe_shard(logits, "batch", None, "vocab")


# --- losses -----------------------------------------------------------------

def sharded_softmax_xent(x: jax.Array, w_out: jax.Array, tokens: jax.Array,
                         z_loss: float = 1e-4) -> jax.Array:
    """Sequence-sharded LM loss: logits stay (batch, seq_act)-sharded.

    With Megatron-SP the final hidden ``x`` arrives sequence-sharded; the
    naive vocab-sharded unembed forces an all-gather of x to full sequence
    (3 GiB fp32 per device on mistral-123b) and an equally large dx
    all-reduce in the backward. Constraining the logits to stay seq-sharded
    makes GSPMD gather the (much smaller) unembed weight instead; lse / gold
    reductions and dx are then fully local. §Perf iteration 2.

    Targets are rolled (not sliced) so the position count stays divisible by
    the mesh axis; the final position is masked out.
    """
    b, s, d = x.shape
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.full((b, 1), -1, tokens.dtype)], axis=1)
    logits = (x @ w_out).astype(jnp.float32)            # (B, S, V)
    logits = maybe_shard(logits, "batch", "seq_act", None)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    valid = (targets >= 0).astype(jnp.float32)
    cnt = jnp.sum(valid)
    loss = jnp.sum((lse - gold) * valid) / cnt
    if z_loss:
        loss = loss + z_loss * jnp.sum(jnp.square(lse) * valid) / cnt
    return loss


def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    z_loss: float = 1e-4) -> jax.Array:
    """Causal LM loss: logits (B,S,V) predict tokens shifted by one.

    The gold logit is extracted with an iota-compare reduction rather than
    ``take_along_axis`` — a gather over the vocab axis would force GSPMD to
    all-gather vocab-sharded logits (tens of GiB at 150k vocab); the
    elementwise compare keeps the whole loss sharded.
    """
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    loss = jnp.mean(lse - gold)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss
