"""FFN variants: SwiGLU, GELU MLP, and SPLIM-dispatch MoE.

MoE is where the paper's technique is a first-class LM feature (DESIGN.md
§3): a top-k routing matrix **is** a row-wise ELLPACK matrix — every token
row has exactly ``k`` non-zero slots, zero padding waste. Dispatch
(``Xᵉ = Rᵀ·X``) and combine (``Y = R·E(Xᵉ)``) are ELLPACK×dense SpMMs.
On TPU the scatter is realized as a one-hot × MXU matmul per tile — exactly
kernels/ell_spmm.py — here expressed as the whole-array einsum so XLA SPMD
can shard it (the Pallas kernel is the single-device tile body; the einsum
is its distributed form).

Three dispatch strategies (config ``moe.dispatch``):
  * 'ellpack' — one-hot dispatch/combine einsums (GShard-style, baseline).
  * 'sort'    — SPLIM-accumulation-style: tokens sorted by expert id (our
    in-situ-search dual), ragged segments, no (T,E,C) one-hot tensor.
    Used by the §Perf hillclimb; ~E× fewer dispatch FLOPs.
  * 'spmm'    — the routing planes feed the SpGEMM stack's structured SpMM
    directly (core.spgemm.spmm_ell_dense off-TPU, kernels/ell_spmm.py's
    one-hot MXU tiles on TPU): dispatch/combine as two ELLPACK×dense
    products, no (T,E,C) tensor, per-layer obs spans from the kernel path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.obs import trace as _obs
from repro.parallel.sharding import maybe_shard

from .params import Spec


# ---------------------------------------------------------------------------
# Dense FFNs
# ---------------------------------------------------------------------------

def swiglu_specs(cfg, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": Spec((d, f), ("fsdp", "ff")),
        "w_up": Spec((d, f), ("fsdp", "ff")),
        "w_down": Spec((f, d), ("ff", "fsdp")),
    }


def swiglu_apply(p, x, dtype):
    h = jax.nn.silu(x @ p["w_gate"].astype(dtype)) * (x @ p["w_up"].astype(dtype))
    axes = ("batch",) + (None,) * (h.ndim - 2) + ("ff",)
    h = maybe_shard(h, *axes)
    return h @ p["w_down"].astype(dtype)


def gelu_mlp_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": Spec((d, f), ("fsdp", "ff")),
        "b_in": Spec((f,), ("ff",), init="zeros"),
        "w_out": Spec((f, d), ("ff", "fsdp")),
        "b_out": Spec((d,), (None,), init="zeros"),
    }


def gelu_mlp_apply(p, x, dtype):
    h = jax.nn.gelu(x @ p["w_in"].astype(dtype) + p["b_in"].astype(dtype))
    h = maybe_shard(h, "batch", None, "ff")
    return h @ p["w_out"].astype(dtype) + p["b_out"].astype(dtype)


# ---------------------------------------------------------------------------
# MoE with ELLPACK dispatch
# ---------------------------------------------------------------------------

def moe_specs(cfg) -> dict:
    m = cfg.moe
    d, fe = cfg.d_model, m.d_ff_expert
    # NO "fsdp" on expert weights: they are already sharded over the model
    # axis (expert and/or expert_ff); adding a data-axis shard would force a
    # per-layer all-gather over data — measured 1.6→0.6e13 collective bytes
    # on deepseek train_4k (§Perf cell B, iteration 4). Optimizer state still
    # shards over data via the ZeRO-1 "opt_shard" rule.
    s = {
        "router": Spec((d, m.n_experts), (None, "expert")),
        "w_gate": Spec((m.n_experts, d, fe), ("expert", None, "expert_ff")),
        "w_up": Spec((m.n_experts, d, fe), ("expert", None, "expert_ff")),
        "w_down": Spec((m.n_experts, fe, d), ("expert", "expert_ff", None)),
    }
    if m.n_shared:
        s["shared"] = swiglu_specs(cfg, d_ff=m.n_shared * fe)
    return s


def _topk_routing(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Returns (weights (T,k) fp32 normalized, expert ids (T,k) int32).

    The (ids, weights) pair is precisely a row-wise ELLPACK representation of
    the T×E routing matrix: k slots per row, idx plane = expert ids.
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, ids.astype(jnp.int32)


def _moe_ellpack(p, x_grp, cfg, dtype):
    """One-hot (ELLPACK) dispatch: GShard-style capacity-bounded einsums,
    *grouped* — x_grp: (G, T_g, d) with G aligned to the data shards, so the
    (G, T_g, E, C_g) dispatch tensor and its einsums shard over "batch" and
    C_g shrinks by G× vs an ungrouped dispatch (§Perf cell A, iteration 1)."""
    m = cfg.moe
    g, tg, d = x_grp.shape
    e, k = m.n_experts, m.top_k
    cap = max(1, int(tg * m.capacity_factor * k / e))
    logits = x_grp @ p["router"].astype(dtype)              # (G,Tg,E)
    w, ids = _topk_routing(logits, k)                       # ELLPACK planes
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)      # (G,Tg,k,E)
    # position of each (token, slot) within its expert's capacity buffer
    pos = jnp.cumsum(onehot.reshape(g, tg * k, e), axis=1).reshape(
        g, tg, k, e) - 1.0
    keep = (pos < cap) & (onehot > 0)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)
    disp = (keep.astype(jnp.float32)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=jnp.float32))  # (G,Tg,k,E,C)
    comb = disp * w[..., None, None]
    disp = disp.sum(2)                                      # (G,Tg,E,C)
    comb = comb.sum(2)
    disp = maybe_shard(disp, "batch", None, "expert", None)
    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(dtype), x_grp)
    xe = maybe_shard(xe, "batch", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(dtype))
    h = maybe_shard(jax.nn.silu(h) * u, "batch", "expert", None, "expert_ff")
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dtype))
    y = jnp.einsum("gtec,gecd->gtd", comb.astype(dtype), ye)
    # load-balancing aux loss (Switch): mean prob per expert × token share
    me = jnp.mean(onehot.sum(2), axis=(0, 1))
    pe = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=(0, 1))
    aux = e * jnp.sum(me * pe)
    return y, aux


def _spmm_ell_auto(a, x):
    """ELLPACK×dense SpMM through the kernel stack: compiled Pallas one-hot
    MXU tiles on TPU (kernels/ell_spmm.py via ops.ell_spmm), the XLA
    segment-sum realization elsewhere — the resolve_mode convention applied
    to the structured multiply."""
    from repro.kernels import ops
    if ops._on_tpu():
        return ops.ell_spmm(a.val, a.idx, x, a.n_rows)
    from repro.core.spgemm import spmm_ell_dense
    return spmm_ell_dense(a, x)


def _moe_spmm(p, x_grp, cfg, dtype):
    """SpGEMM-stack dispatch: the top-k routing planes (ids, weights) *are*
    a row-wise ELLPACK matrix (``_topk_routing``), so dispatch and combine
    run as two structured ELLPACK×dense SpMMs through ``_spmm_ell_auto`` —
    the same op behind SparseLinear — instead of materializing the
    (T, E, C) one-hot tensor. Dispatch scatters token rows into per-expert
    capacity slots (k slabs, slot coordinate = expert·cap + rank); combine
    gathers them back with the routing weights as a 1-slab ELLPACK over the
    slot axis (each slot holds at most one pair). Numerically equivalent to
    'ellpack' up to float summation order."""
    m = cfg.moe
    g, tg, d = x_grp.shape
    e, k = m.n_experts, m.top_k
    cap = max(1, int(tg * m.capacity_factor * k / e))
    logits = x_grp @ p["router"].astype(dtype)              # (G,Tg,E)
    w, ids = _topk_routing(logits, k)                       # ELLPACK planes
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)      # (G,Tg,k,E)
    pos = jnp.cumsum(onehot.reshape(g, tg * k, e), axis=1).reshape(
        g, tg, k, e) - 1.0
    keep = (pos < cap) & (onehot > 0)
    rank = jnp.where(keep, pos, 0).sum(-1).astype(jnp.int32)  # (G,Tg,k)
    kept = keep.any(-1)                                       # (G,Tg,k)
    slot = ids * cap + rank                                   # in [0, E·C)

    from repro.core.formats import EllRows

    def one_group(x_g, slot_g, kept_g, w_g):
        # dispatch: k-slab ELLPACK, columns = tokens, rows = E·C slots
        disp = EllRows(
            val=kept_g.astype(dtype).T,                       # (k, Tg)
            idx=jnp.where(kept_g, slot_g, -1).T.astype(jnp.int32),
            n_rows=e * cap)
        xe = _spmm_ell_auto(disp, x_g).reshape(e, cap, d)
        h = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dtype))
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                        p["w_down"].astype(dtype)).reshape(e * cap, d)
        # combine: invert slot→(token, weight); ranks are unique per expert
        # so every slot holds ≤ 1 pair and the scatter is deterministic
        flat = jnp.where(kept_g, slot_g, e * cap).reshape(-1)
        tok = jnp.broadcast_to(
            jnp.arange(tg, dtype=jnp.int32)[:, None], (tg, k)).reshape(-1)
        tok_of = jnp.full((e * cap + 1,), -1, jnp.int32) \
            .at[flat].set(tok)[: e * cap]
        w_of = jnp.zeros((e * cap + 1,), dtype) \
            .at[flat].set(w_g.reshape(-1).astype(dtype))[: e * cap]
        comb = EllRows(val=w_of[None], idx=tok_of[None], n_rows=tg)
        return _spmm_ell_auto(comb, ye)                       # (Tg, d)

    y = jax.vmap(one_group)(x_grp, slot, kept, w)
    me = jnp.mean(onehot.sum(2), axis=(0, 1))
    pe = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=(0, 1))
    aux = e * jnp.sum(me * pe)
    return y, aux


def _moe_sort(p, x_grp, cfg, dtype):
    """SPLIM-style sorted dispatch (grouped): sort (token,slot) pairs by
    expert id — the in-situ-search dual (equal coordinates grouped by
    sorting) — then gather/scatter into per-expert capacity buffers. No
    (T,E,C) one-hot tensor is ever materialized; dispatch cost drops from
    O(T·E·C·d) to O(T·k·d + sort). §Perf cell A, iteration 2.

    The whole dispatch→expert→combine region runs under a *full-manual*
    shard_map: GSPMD cannot prove that each group's dispatch indices stay
    inside that group's slice and falls back to replicate+all-reduce of the
    full (T·k, d) buffers (measured 48 GiB f32 all-reduces per layer on
    deepseek). Inside shard_map every gather/scatter is group-local; expert
    weights arrive pre-sliced over the model axis (expert dim when it
    divides, hidden dim otherwise) and one psum over "model" merges the
    partial combine. §Perf iteration 5."""
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import current_rules
    m = cfg.moe
    g, tg, d = x_grp.shape
    e, fe = m.n_experts, m.d_ff_expert

    rules = current_rules()
    if rules is None or rules.mesh is None:
        return _moe_sort_body(x_grp, p["router"], p["w_gate"], p["w_up"],
                              p["w_down"], cfg, dtype, (), ())

    mesh = rules.mesh
    gspec = rules.resolve(("batch", None, None), x_grp.shape)
    gaxes = (() if gspec[0] is None else
             (gspec[0] if isinstance(gspec[0], tuple) else (gspec[0],)))
    wg_spec = rules.resolve(("expert", None, "expert_ff"), (e, d, fe))
    wd_spec = rules.resolve(("expert", "expert_ff", None), (e, fe, d))
    # model-axis handle for the expert offset / final psum
    model_axes = tuple(ax for ax in ("model",) if ax in mesh.shape)

    def body(x_loc, router, wg, wu, wd):
        return _moe_sort_body(x_loc, router, wg, wu, wd, cfg, dtype,
                              gaxes, model_axes)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(gspec[0], None, None), P(), wg_spec, wg_spec, wd_spec),
        out_specs=(P(gspec[0], None, None), P()),
        check_vma=False)
    return fn(x_grp, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def _moe_sort_body(x_grp, router, w_gate, w_up, w_down, cfg, dtype,
                   gaxes, model_axes):
    """Manual (device-local) sort dispatch. Expert weights may arrive sliced
    on the expert dim (e_loc < E) or the hidden dim; in either case the
    combine is partial and one psum over the model axis completes it."""
    m = cfg.moe
    g, tg, d = x_grp.shape
    e, k = m.n_experts, m.top_k
    cap = max(1, int(tg * m.capacity_factor * k / e))
    e_loc = w_gate.shape[0]
    if model_axes and e_loc < e:
        e_off = jax.lax.axis_index(model_axes[0]) * e_loc
    else:
        e_off = jnp.zeros((), jnp.int32)

    logits = x_grp @ router.astype(dtype)                   # (G,Tg,E)
    w, ids = _topk_routing(logits, k)

    # per-group sort along axis 1 (lax.sort dimension=1): every group sorts
    # its own (token, slot) pairs by expert id in parallel — the G dim stays
    # explicit so GSPMD keeps all dispatch structures data-sharded. Integers
    # only: the differentiable payload is gathered afterwards by permutation,
    # so autodiff never sees the sort.
    npg = tg * k                                             # pairs per group
    tok_of = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, npg))
    iota_g = jnp.broadcast_to(jnp.arange(npg, dtype=jnp.int32)[None], (g, npg))
    s_ids, s_tok, perm = jax.lax.sort(
        (ids.reshape(g, npg), tok_of, iota_g),
        dimension=1, num_keys=1, is_stable=True)
    goff_p = (jnp.arange(g, dtype=jnp.int32) * npg)[:, None]
    s_w = w.reshape(g * npg)[(perm + goff_p).reshape(-1)].reshape(g, npg)
    # rank within each (group, expert) run
    same = jnp.concatenate(
        [jnp.zeros((g, 1), jnp.int32),
         (s_ids[:, 1:] == s_ids[:, :-1]).astype(jnp.int32)], axis=1)
    idx = jnp.broadcast_to(jnp.arange(npg)[None], (g, npg))
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(same == 0, idx, 0), axis=1)
    rank = idx - run_start
    keep = rank < cap
    slot = s_ids * cap + jnp.where(keep, rank, 0)            # (G, npg) in E·C
    # gather tokens (flat indices carry the group sharding)
    goff_t = (jnp.arange(g, dtype=jnp.int32) * tg)[:, None]
    gathered = x_grp.reshape(g * tg, d)[((s_tok + goff_t)).reshape(-1)]
    gathered = (gathered.reshape(g, npg, d)
                * keep[..., None].astype(dtype))
    # scatter-add into per-group expert capacity buffers
    goff_s = (jnp.arange(g, dtype=jnp.int32) * (e * cap))[:, None]
    flat_slot = jnp.where(keep, slot + goff_s, g * e * cap).reshape(-1)
    xe = jax.ops.segment_sum(gathered.reshape(g * npg, d), flat_slot,
                             num_segments=g * e * cap + 1)[:-1]
    xe = xe.reshape(g, e, cap, d)
    # slice to the experts whose weights live on this device
    xe_loc = jax.lax.dynamic_slice_in_dim(xe, e_off, e_loc, axis=1)
    h = jnp.einsum("gecd,edf->gecf", xe_loc, w_gate.astype(dtype))
    u = jnp.einsum("gecd,edf->gecf", xe_loc, w_up.astype(dtype))
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * u,
                    w_down.astype(dtype))
    # combine only the pairs whose expert is local; psum completes the rest
    loc_slot = slot - e_off * cap
    in_range = jnp.logical_and(loc_slot >= 0, loc_slot < e_loc * cap)
    loc_slot = jnp.clip(loc_slot, 0, e_loc * cap - 1)
    goff_l = (jnp.arange(g, dtype=jnp.int32) * (e_loc * cap))[:, None]
    back = (ye.reshape(g * e_loc * cap, d)[(loc_slot + goff_l).reshape(-1)]
            .reshape(g, npg, d)
            * (s_w * keep * in_range).astype(dtype)[..., None])
    y = jax.ops.segment_sum(back.reshape(g * npg, d),
                            ((s_tok + goff_t)).reshape(-1),
                            num_segments=g * tg).reshape(g, tg, d)
    # psum only when the model axis actually partitioned the expert compute
    # (expert dim or hidden dim sliced) — otherwise y is already complete
    partitioned = (e_loc < e) or (w_gate.shape[2] < m.d_ff_expert)
    if model_axes and partitioned:
        y = jax.lax.psum(y, model_axes)
    onehot = jax.nn.one_hot(ids, e, dtype=jnp.float32)
    me = jnp.mean(onehot.sum(2), axis=(0, 1))
    pe = jnp.mean(jax.nn.softmax(logits.astype(jnp.float32), -1), axis=(0, 1))
    aux = e * jnp.sum(me * pe)
    if gaxes:
        aux = jax.lax.pmean(aux, gaxes)
    return y, aux


class SparseMLP:
    """Pruned two-layer MLP whose layers pool one structure cache.

    Both :class:`~repro.models.sparse.SparseLinear` layers share a single
    ``plan.cache.StructureCache``: a serving loop that applies the MLP to
    recurring sparse-activation patterns pays the symbolic SpGEMM phase once
    per (pattern, layer) and runs numeric-only afterwards, with one shared
    LRU/stats surface for the whole block (pass ``cache=`` to pool wider,
    e.g. the engine-level cache in serve/engine.py).
    """

    def __init__(self, w_in: jax.Array, w_out: jax.Array, sparsity: float, *,
                 cache=None, cache_capacity: int = 16, nm="auto"):
        from repro.plan.cache import StructureCache
        from .sparse import SparseLinear
        self.cache = cache if cache is not None \
            else StructureCache(capacity=cache_capacity)
        self.fc_in = SparseLinear(w_in, sparsity, cache=self.cache, nm=nm)
        self.fc_out = SparseLinear(w_out, sparsity, cache=self.cache, nm=nm)

    def __call__(self, x: jax.Array) -> jax.Array:
        """Dense activations: x @ W_in → GELU → @ W_out (structured SpMMs)."""
        with _obs.span("sparse_mlp.apply"):
            return _obs.sync(self.fc_out(jax.nn.gelu(self.fc_in(x))))

    def cache_stats(self):
        """Hit/miss/eviction counters of the shared structure cache."""
        return self.cache.stats()


def moe_apply(p, x, cfg, dtype) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y, aux_loss). Tokens are grouped by data shard (GShard
    groups) so dispatch structures shard over "batch" and per-group capacity
    stays constant as the fleet scales."""
    from repro.parallel.sharding import axis_size
    b, s, d = x.shape
    t = b * s
    groups = max(1, min(axis_size("batch"), b))
    x_grp = x.reshape(groups, t // groups, d)
    with _obs.span("moe.dispatch", strategy=cfg.moe.dispatch,
                   tokens=t, experts=cfg.moe.n_experts):
        if cfg.moe.dispatch == "sort":
            y, aux = _moe_sort(p, x_grp, cfg, dtype)
        elif cfg.moe.dispatch == "spmm":
            y, aux = _moe_spmm(p, x_grp, cfg, dtype)
        else:
            y, aux = _moe_ellpack(p, x_grp, cfg, dtype)
        _obs.sync(y)
    if cfg.moe.n_shared:
        y = y + swiglu_apply(p["shared"], x_grp, dtype)
    return y.reshape(b, s, d), aux
