"""LM model zoo: dense GQA, MLA, MoE (SPLIM dispatch), Mamba, RG-LRU, enc-dec."""
from .api import Model, build_model

__all__ = ["Model", "build_model"]
