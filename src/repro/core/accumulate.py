"""Unstructured accumulation via the in-situ-search equivalent (paper §III-B).

SPLIM's hardware repeatedly bit-serial-searches the (RI, CI) planes for the
minimum coordinate, emitting groups with equal coordinates in sorted order and
summing each group on a small accumulator (Alg. 1 + Fig. 11). The *output
contract* is: a sorted, duplicate-free COO stream, produced without a
scheduler and without a dense intermediate.

TPU has no leakage-current search primitive, so we realize the same contract
with the TPU-native dual (DESIGN.md §2): a **stable multi-key sort** of the
coordinate planes followed by a **segmented sum**. ``jax.lax.sort`` with
``num_keys=2`` is a lexicographic (row, col) sort — invalid lanes are parked
at row = n_rows so they fall to the tail, exactly like the paper flipping the
sign bit to invalidate consumed coordinates.

The Pallas kernel (kernels/bitonic_merge.py) is the explicitly tiled
in-VMEM version for coordinate spaces that fit 16-bit tiles.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .formats import Coo, INVALID


def sort_by_coords(row: jax.Array, col: jax.Array, val: jax.Array,
                   n_rows: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Lexicographic (row, col) sort; invalid entries sink to the tail."""
    row = row.reshape(-1)
    col = col.reshape(-1)
    val = val.reshape(-1)
    park = row < 0
    row_s = jnp.where(park, n_rows, row)          # sentinel sorts last
    col_s = jnp.where(park, 0, col)
    row_s, col_s, val_s = jax.lax.sort(
        (row_s, col_s, val), dimension=0, num_keys=2, is_stable=False)
    row_o = jnp.where(row_s >= n_rows, INVALID, row_s)
    col_o = jnp.where(row_s >= n_rows, INVALID, col_s)
    val_o = jnp.where(row_s >= n_rows, 0, val_s)
    return row_o, col_o, val_o


class AccumulatorOverflow(ValueError):
    """The true unique-coordinate count exceeded the static ``out_cap``."""


def merge_sorted(row: jax.Array, col: jax.Array, val: jax.Array,
                 out_cap: int, n_rows: int, n_cols: int) -> Coo:
    """Coalesce a coordinate-sorted stream: sum runs of equal (row, col).

    Static output size ``out_cap``; if the true number of unique coordinates
    exceeds it the stored stream is truncated (callers size out_cap from
    hwmodel / upper bounds) — but the returned ``Coo`` carries ``ngroups``,
    the TRUE group count, so truncation is detectable (``coo.overflowed()``
    in-graph, ``check_no_overflow`` on the host). This is the "on-chip
    accumulator" epilogue of Fig. 11(c).
    """
    valid = row >= 0
    new_grp = jnp.logical_or(row != jnp.roll(row, 1), col != jnp.roll(col, 1))
    new_grp = new_grp.at[0].set(True)
    new_grp = jnp.logical_and(new_grp, valid)
    seg = jnp.cumsum(new_grp.astype(jnp.int32)) - 1          # group id, -1 before first
    seg = jnp.where(valid, seg, out_cap)                      # park invalid
    seg = jnp.clip(seg, 0, out_cap)                           # truncate overflow
    sums = jax.ops.segment_sum(val, seg, num_segments=out_cap + 1)[:out_cap]
    # representative coordinates per group = first element of each run
    first = jnp.where(new_grp, jnp.arange(row.shape[0]), row.shape[0] - 1)
    first_idx = jax.ops.segment_min(first, seg, num_segments=out_cap + 1)[:out_cap]
    ngroups = jnp.sum(new_grp)
    slot_ok = jnp.arange(out_cap) < ngroups
    out_row = jnp.where(slot_ok, row[first_idx], INVALID).astype(jnp.int32)
    out_col = jnp.where(slot_ok, col[first_idx], INVALID).astype(jnp.int32)
    out_val = jnp.where(slot_ok, sums, 0)
    return Coo(row=out_row, col=out_col, val=out_val, shape=(n_rows, n_cols),
               ngroups=ngroups.astype(jnp.int32))


def accumulate(row: jax.Array, col: jax.Array, val: jax.Array,
               out_cap: int, n_rows: int, n_cols: int) -> Coo:
    """sort + merge: the full in-situ-search-equivalent accumulation."""
    r, c, v = sort_by_coords(row, col, val, n_rows)
    return merge_sorted(r, c, v, out_cap, n_rows, n_cols)


def check_no_overflow(coo: Coo) -> Coo:
    """Host-side guard: raise ``AccumulatorOverflow`` if the producer dropped
    groups beyond ``cap``. Call outside jit (forces a sync on ``ngroups``);
    inside traced code use ``coo.overflowed()`` and route the flag out.
    Accepts batched ``Coo`` (leading axis on ``ngroups``, e.g. from
    ``spgemm_coo_batched``): raises if ANY batch entry overflowed.
    """
    if coo.ngroups is None:
        return coo
    import numpy as np
    ngroups = np.asarray(jax.device_get(coo.ngroups))
    cap = coo.row.shape[-1]
    worst = int(ngroups.max())
    if worst > cap:
        n_bad = int((ngroups > cap).sum()) if ngroups.ndim else 1
        where = "" if ngroups.ndim == 0 else f" in {n_bad} batch entr{'y' if n_bad == 1 else 'ies'}"
        # exactly one event per offending call (not per batch entry)
        from repro.obs import metrics as _obs_metrics
        from repro.obs import trace as _obs
        _obs_metrics.inc("spgemm.overflow_events")
        _obs.instant("spgemm.overflow", worst=worst, cap=cap, n_bad=n_bad)
        raise AccumulatorOverflow(
            f"accumulation produced up to {worst} unique coordinates but "
            f"out_cap={cap}{where}; {worst - cap} group(s) were dropped — "
            f"resize out_cap (e.g. from hwmodel upper bounds)")
    return coo


def accumulate_checked(row: jax.Array, col: jax.Array, val: jax.Array,
                       out_cap: int, n_rows: int, n_cols: int) -> Coo:
    """``accumulate`` + host-side overflow check (raises on truncation)."""
    return check_no_overflow(accumulate(row, col, val, out_cap,
                                        n_rows, n_cols))


def scatter_dense(row: jax.Array, col: jax.Array, val: jax.Array,
                  n_rows: int, n_cols: int) -> jax.Array:
    """Decompression-style accumulation into a dense C — this is what
    COO-SPLIM / GraphR do (paper Fig. 5 / Fig. 9b). Kept as the oracle and as
    the explicit baseline the paper argues against."""
    r = jnp.where(row.reshape(-1) >= 0, row.reshape(-1), n_rows)
    c = jnp.where(col.reshape(-1) >= 0, col.reshape(-1), 0)
    dense = jnp.zeros((n_rows + 1, n_cols), val.dtype)
    dense = dense.at[r, c].add(jnp.where(row.reshape(-1) >= 0, val.reshape(-1), 0))
    return dense[:n_rows]
