"""Distributed SPLIM SpGEMM — sparse-native ring schedules on the ICI torus.

Paper Fig. 6(c): B column-vectors rotate array→array (2-step RowClone) while
A row-vectors stay put; every array multiplies its resident A slabs against
the visiting B slabs; intermediate results never cross arrays (§VI-D:
"SPLIM circumvents the need for cross-PE transfer of intermediate results").

TPU mapping: the array ring is a mesh-axis ring, RowClone is
``jax.lax.ppermute`` (one ICI hop, no shared-bus conflicts at all — stronger
than the paper's 2-phase odd/even RowClone schedule), and the per-array
multiply is the SCCP slab product.  What happens *after* the multiply is the
point of this module: partial products are accumulated **device-locally and
sparsely** (the planner's sort/tiled/bucket/hash/stream backends), and only
**COO triples binned by output-row owner** ever cross the mesh — a
propagation-blocking exchange in the spirit of Gu et al. (arXiv 2002.11302)
— so no path here materializes a dense ``n_rows × n_cols`` array.

Three schedules (selected by ``plan.make_dist_plan``):

  * ``'ring'``  — B-stationary ring (paper Fig. 6c): A slabs stay sharded,
    B slabs rotate; each device accumulates its slab-pair product stream
    into a local sorted COO, then a ``ring_all_to_all`` exchanges the
    partials binned by the row-block owner, who merges them.
  * ``'cstat'`` — C-stationary row-block ownership: every device masks A to
    the output rows it owns and merges each visiting-B-slab product stream
    straight into its resident C block — intermediates *never* cross the
    mesh (only operand slabs rotate), at the price of replicating A.
  * ``'summa'`` — communication-avoiding 2D schedule (SUMMA-style; Gu &
    Azad arXiv 2002.11302, Deveci et al.): the device axis is factored into
    a logical ``pr × pc`` grid; each device assembles its grid row's A slab
    panel over ``pc−1`` neighbour hops along the row ring, then rotates B
    panels ``pr−1`` hops along the column ring — per-device operand motion
    is ``(pc−1)/p`` of A plus ``(pr−1)/p`` of B, ~``1/√p`` of the 1D ring's
    full-B volume — and finishes with the same owner-binned COO exchange as
    ``'ring'``. Both 1D schedules rotate over the whole ring; 2D exchanges
    along mesh rows/columns only, which is what survives large meshes.

All three support ``overlap=True`` double-buffering: each stage's
``ppermute`` prefetch of the *next* operand panel is issued before the
current stage's products are accumulated, and the pair is rejoined with
``compat.optimization_barrier`` — on hardware with an async ICI the
exchange hides entirely behind the accumulation scan, and numerics are
bit-identical either way (the barrier only pins scheduling).

Output stays ``Coo`` end to end; ``ngroups`` overflow poisoning (local-cap
truncation, full exchange bins, block-cap truncation) is ``psum``-reduced
across the collective so ``check_no_overflow`` sees every device's drops.

``ring_spgemm`` (dense per-device partial C + final ``psum``) is kept as the
explicit dense baseline the sparse path replaces — it is what COO-SPLIM/
GraphR-style decompression would do, and the distributed benchmark suite
measures its per-device partial-memory cost against ``spgemm_coo_sharded``.

The same ring schedule is reused by the LM stack for MoE token exchange
(models/moe.py, ``ring_all_to_all``) — SPLIM's communication pattern promoted
to a first-class collective strategy.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import axis_size, optimization_barrier, pvary, shard_map
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs

from .accumulate import accumulate, scatter_dense
from .formats import Coo, EllCols, EllRows, INVALID


# ---------------------------------------------------------------------------
# Slab padding (ISSUE: validate-and-pad instead of opaque reshape errors)
# ---------------------------------------------------------------------------

def pad_slabs_a(a: EllRows, mult: int) -> EllRows:
    """Pad A's slab axis to a multiple of ``mult`` with INVALID lanes.

    Padding slabs carry ``idx = -1`` / ``val = 0`` so they contribute no
    products — the distributed schedules shard the slab axis over the mesh
    ring and require it divisible by the ring size.
    """
    if a.val.shape[-2] % mult == 0:          # slab axis (batched-safe)
        return a
    from repro.kernels.ops import pad_to
    return EllRows(val=pad_to(a.val, -2, mult, 0),
                   idx=pad_to(a.idx, -2, mult, INVALID), n_rows=a.n_rows)


def pad_slabs_b(b: EllCols, mult: int) -> EllCols:
    """Pad B's slab axis to a multiple of ``mult`` with INVALID lanes."""
    if b.val.shape[-1] % mult == 0:          # slab axis (batched-safe)
        return b
    from repro.kernels.ops import pad_to
    return EllCols(val=pad_to(b.val, -1, mult, 0),
                   idx=pad_to(b.idx, -1, mult, INVALID), n_cols=b.n_cols)


# ---------------------------------------------------------------------------
# Shared building blocks
# ---------------------------------------------------------------------------

def _slab_products(a_val, a_idx, b_val, b_idx):
    """Resident-A × visiting-B slab products (works with leading batch dims).

    Returns ``(val, row, col)`` of shape ``(..., ka_loc, n, kb_loc)`` with
    invalid lanes carrying row = col = -1 and val = 0.
    """
    val = a_val[..., :, :, None] * b_val[..., None, :, :]
    row = jnp.broadcast_to(a_idx[..., :, :, None], val.shape)
    col = jnp.broadcast_to(b_idx[..., None, :, :], val.shape)
    ok = (row >= 0) & (col >= 0)
    return (jnp.where(ok, val, 0),
            jnp.where(ok, row, INVALID),
            jnp.where(ok, col, INVALID))


def _bin_by_owner(row: jax.Array, col: jax.Array, val: jax.Array,
                  n_dev: int, rows_per_dev: int, bin_cap: int):
    """Scatter a row-sorted local COO into per-owner exchange bins.

    Entries are already (row, col)-sorted with invalid lanes parked at the
    tail (every accumulation backend's output contract), so each owner's
    entries form one contiguous run: rank-in-bin = position − run start.
    Returns ``(n_dev, bin_cap)`` row/col/val planes plus the number of
    entries dropped to full bins (0 under a ``make_dist_plan`` sizing).
    """
    cap = row.shape[0]
    valid = row >= 0
    owner = jnp.where(valid, row // rows_per_dev, n_dev)
    counts = jax.ops.segment_sum(jnp.ones((cap,), jnp.int32), owner,
                                 num_segments=n_dev + 1)
    start = jnp.cumsum(counts) - counts                  # exclusive prefix
    rank = jnp.arange(cap, dtype=jnp.int32) - start[owner]
    keep = valid & (rank < bin_cap)
    dropped = jnp.sum(valid & ~keep).astype(jnp.int32)
    o = jnp.where(keep, owner, n_dev)                    # dump bin n_dev
    r = jnp.where(keep, rank, 0)
    buf_row = (jnp.full((n_dev + 1, bin_cap), INVALID, jnp.int32)
               .at[o, r].set(jnp.where(keep, row, INVALID)))
    buf_col = (jnp.full((n_dev + 1, bin_cap), INVALID, jnp.int32)
               .at[o, r].set(jnp.where(keep, col, INVALID)))
    buf_val = (jnp.zeros((n_dev + 1, bin_cap), val.dtype)
               .at[o, r].set(jnp.where(keep, val, 0)))
    return buf_row[:n_dev], buf_col[:n_dev], buf_val[:n_dev], dropped


def _compact_sorted(row: jax.Array, col: jax.Array, val: jax.Array,
                    out_cap: int, shape: Tuple[int, int],
                    ngroups: jax.Array) -> Coo:
    """Dense-pack a globally sorted, gappy COO stream into ``Coo(out_cap)``.

    The per-device row blocks arrive owner-ordered (ascending row ranges)
    and block-sorted, so valid entries are already in global (row, col)
    order — an O(n) cumsum scatter packs them without re-sorting. Valid
    entries beyond ``out_cap`` land in the discarded dump slot; the caller's
    ``ngroups`` (true global group count, possibly poisoned) flags that.
    """
    valid = row >= 0
    dst = jnp.minimum(jnp.where(valid, jnp.cumsum(valid) - 1, out_cap),
                      out_cap)
    out_row = (jnp.full((out_cap + 1,), INVALID, jnp.int32)
               .at[dst].set(jnp.where(valid, row, INVALID)))[:out_cap]
    out_col = (jnp.full((out_cap + 1,), INVALID, jnp.int32)
               .at[dst].set(jnp.where(valid, col, INVALID)))[:out_cap]
    out_val = (jnp.zeros((out_cap + 1,), val.dtype)
               .at[dst].set(jnp.where(valid, val, 0)))[:out_cap]
    return Coo(row=out_row, col=out_col, val=out_val, shape=shape,
               ngroups=ngroups)


# ---------------------------------------------------------------------------
# Sparse-native distributed SpGEMM
# ---------------------------------------------------------------------------

def spgemm_coo_sharded(a: EllRows, b: EllCols, mesh: Mesh, axis: str,
                       out_cap="auto", *, accumulator: str = "auto",
                       schedule: str = "auto", dist_plan=None,
                       structure=None, overlap: bool = True,
                       check: bool = False) -> Coo:
    """C = A·B as sorted COO with slabs sharded over the mesh axis ``axis``.

    Prefer ``repro.spgemm(a, b, mesh=mesh, axis=axis, ...)`` — the unified
    front door (core/api.py) delegates here with identical kwargs.

    Sparse end to end: each ring step feeds the SCCP slab product into a
    device-local planned accumulator, and only COO triples cross the mesh
    (see module docstring for the three schedules — ``'ring'``/``'cstat'``
    1D rotations and the communication-avoiding 2D ``'summa'`` grid). The
    result is replicated
    and bit-compatible with single-device ``spgemm_coo``: same sorted
    coordinate stream, same padding, same true-``ngroups`` overflow
    contract — with any device's drops poisoning the global count.

    ``overlap=True`` (default) double-buffers every schedule's operand
    rotation: the next panel's ``ppermute`` is issued *before* the current
    panel's products are accumulated and the two are rejoined with
    ``compat.optimization_barrier``, hiding the exchange behind compute on
    async-ICI hardware. Purely a scheduling hint — results are bit-identical
    with ``overlap=False`` (which restores accumulate-then-rotate order).

    ``out_cap`` / ``accumulator`` / ``schedule`` accept ``'auto'`` (requires
    concrete operands — planning inspects values); a prebuilt ``dist_plan``
    (``plan.make_dist_plan``) supplies all capacities and keeps the call
    jit/vmap-friendly; a ``structure`` (``plan.make_structure(...,
    n_dev=...)``) supplies its cached per-schedule DistPlan the same way, so
    repeat calls on one pattern never re-plan. A caller-supplied dist_plan
    is fingerprint-validated against the operands (see ``Plan.fp``); stale
    plans raise instead of silently truncating. Batched operands (leading
    batch axis on all four
    ELLPACK planes) are supported with an explicit ``dist_plan`` built on a
    representative slice. ``check=True`` raises ``AccumulatorOverflow`` on
    any truncation anywhere in the pipeline (host sync; call outside jit).

    Coordinate spaces with ``n_rows·n_cols ≥ 2³¹`` reroute the device-local
    accumulation to the unpacked two-key ``'sort'`` path regardless of the
    requested backend — the same automatic, lossless rerouting
    ``spgemm_coo`` applies (packed int32 keys cannot span such spaces).

    ``accumulator='stream'`` moves accumulation *inside* the ring scan
    (core.streaming): each step's slab products are compacted and merged
    into a running sorted buffer immediately, so the per-device peak
    intermediate is one (ka_loc, n, kb_loc) step tile plus the buffer —
    the other backends stack all ``n_dev`` steps' products before
    accumulating.
    """
    n_dev = mesh.shape[axis]
    batched = a.val.ndim == 3
    if dist_plan is None and structure is not None:
        # Per-schedule DistPlan reuse: a SpgemmStructure built with n_dev=
        # caches one DistPlan per schedule — repeated sharded calls on the
        # same pattern skip make_dist_plan entirely.
        dist_plan = structure.dist_plan(
            None if schedule == "auto" else schedule)
        if out_cap == "auto":
            out_cap = structure.out_cap
    if dist_plan is None:
        if isinstance(a.val, jax.core.Tracer) or batched:
            raise ValueError(
                "spgemm_coo_sharded needs a dist_plan under jit/vmap or with "
                "batched operands — build one with plan.make_dist_plan on a "
                "representative (concrete, unbatched) slice and pass "
                "dist_plan=")
        from repro.plan import make_dist_plan
        dist_plan = make_dist_plan(
            a, b, n_dev=n_dev,
            out_cap=None if out_cap == "auto" else int(out_cap),
            backend=None if accumulator == "auto" else accumulator,
            schedule=None if schedule == "auto" else schedule)
    dp = dist_plan
    if dp.n_dev != n_dev:
        raise ValueError(f"dist_plan built for {dp.n_dev} devices but mesh "
                         f"axis {axis!r} has {n_dev}")
    from .spgemm import _validate_plan_fp
    _validate_plan_fp(dp, a, b)
    out_cap = dp.out_cap if out_cap == "auto" else int(out_cap)
    sched = dp.schedule if schedule == "auto" else schedule
    if sched not in ("ring", "cstat", "summa"):
        raise ValueError(f"unknown schedule {sched!r}")
    pr, pc = dp.pr, dp.pc
    if sched == "summa" and pr * pc != n_dev:
        # hand-built or pre-grid DistPlan: derive the factorization here
        # (capacities stay safe — local_cap covers both 1D and 2D histograms
        # under make_dist_plan, and hand caps are the caller's contract)
        from repro.plan.planner import best_grid
        pr, pc = best_grid(n_dev, a.val.shape[-2], b.val.shape[-1],
                           allow_degenerate=True)
    backend = dp.base.backend if accumulator == "auto" else accumulator
    if a.n_rows * b.n_cols >= jnp.iinfo(jnp.int32).max:
        backend = "sort"                     # only unpacked keys span this
    a = pad_slabs_a(a, n_dev)
    b = pad_slabs_b(b, n_dev)
    n_rows, n_cols = a.n_rows, b.n_cols
    rpd, local_cap = dp.rows_per_dev, dp.local_cap
    bin_cap, block_cap = dp.bin_cap, dp.block_cap
    from .spgemm import accumulate_stream
    from . import streaming
    base = dp.base
    use_stream = backend == "stream"

    def acc_local(r, c, v):
        return accumulate_stream(r.reshape(-1), c.reshape(-1), v.reshape(-1),
                                 local_cap, n_rows, n_cols, backend=backend,
                                 tile=base.tile, plan=base)

    def merge_step(r, c, v):
        return accumulate_stream(r, c, v, block_cap, n_rows, n_cols,
                                 backend=backend, tile=base.tile, plan=None)

    def absorb(st, r, c, v):
        # one ring step's (ka_loc, n, kb_loc) products as a single tile:
        # the step already materialized it, so per-device peak intermediate
        # is that tile + the running buffer, never the stacked n_dev-step
        # stream the non-stream path collects before accumulating.
        from repro.kernels.bitonic_merge import next_pot
        return streaming.absorb_products(
            st, r.reshape(-1), c.reshape(-1), v.reshape(-1), n_cols=n_cols,
            stream_cap=next_pot(r.size))

    perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    vb = (lambda f: jax.vmap(f)) if batched else (lambda f: f)
    # device-stacked scan outputs / exchange buffers carry the mesh axis
    # first and the batch axis (if any) second; flatten per matrix.
    flat = ((lambda x: jnp.moveaxis(x, 1, 0).reshape(x.shape[1], -1))
            if batched else (lambda x: x.reshape(-1)))

    def rotate(bv, bi, p):
        return (jax.lax.ppermute(bv, axis, p),
                jax.lax.ppermute(bi, axis, p))

    def exchange_tail(local, poison):
        # owner-binned COO exchange + per-owner block merge, shared by the
        # B-stationary 1D ring and the 2D summa grid (owners are flat device
        # ids over the full axis either way)
        poison = poison + (local.ngroups > local_cap).astype(jnp.int32)
        br, bc, bv_, dropped = vb(partial(
            _bin_by_owner, n_dev=n_dev, rows_per_dev=rpd,
            bin_cap=bin_cap))(local.row, local.col, local.val)
        poison = poison + (dropped > 0).astype(jnp.int32)
        if batched:                          # exchange wants the mesh axis first
            br, bc, bv_ = (jnp.moveaxis(t, 1, 0) for t in (br, bc, bv_))
        got_i = ring_all_to_all(jnp.stack([br, bc], axis=-1), axis)
        got_v = ring_all_to_all(bv_, axis)
        block = vb(partial(accumulate, out_cap=block_cap, n_rows=n_rows,
                           n_cols=n_cols))(
            flat(got_i[..., 0]), flat(got_i[..., 1]), flat(got_v))
        poison = poison + (block.ngroups > block_cap).astype(jnp.int32)
        ng = (jax.lax.psum(block.ngroups, axis)
              + jnp.where(jax.lax.psum(poison, axis) > 0,
                          jnp.int32(out_cap + 1), jnp.int32(0)))
        return block.row[None], block.col[None], block.val[None], ng

    def rotating_products(av, ai, b_val, b_idx, p, steps, lead):
        """Run ``steps`` rotation stages of resident(av, ai) × visiting B,
        accumulating device-locally; returns the local sorted Coo.

        With ``overlap`` the next panel's ppermute is issued before this
        panel's products are accumulated; ``optimization_barrier`` rejoins
        the prefetched buffers with the accumulation result so XLA cannot
        sink the transfer below the compute it should hide behind.
        """
        if use_stream:
            st0 = streaming.stream_init(streaming.buffer_cap(local_cap),
                                        av.dtype, lead=lead)

            def step(carry, _):
                bv, bi, st = carry
                if overlap:
                    nbv, nbi = rotate(bv, bi, p)
                    v, r, c = _slab_products(av, ai, bv, bi)
                    st = vb(absorb)(st, r, c, v)
                    (nbv, nbi), st = optimization_barrier(((nbv, nbi), st))
                else:
                    v, r, c = _slab_products(av, ai, bv, bi)
                    st = vb(absorb)(st, r, c, v)
                    nbv, nbi = rotate(bv, bi, p)
                return (nbv, nbi, st), ()
            (_, _, st), _ = jax.lax.scan(step, (b_val, b_idx, st0), None,
                                         length=steps)
            return vb(partial(streaming.finalize, out_cap=local_cap,
                              n_rows=n_rows, n_cols=n_cols))(st)

        def step(carry, _):
            bv, bi = carry
            if overlap:
                nxt = rotate(bv, bi, p)
                prod = _slab_products(av, ai, bv, bi)
                nxt, prod = optimization_barrier((nxt, prod))
                return nxt, prod
            prod = _slab_products(av, ai, bv, bi)
            return rotate(bv, bi, p), prod
        # vs/rs/cs: (steps, [batch,] ka_loc, n, kb_loc) — the device-local
        # product stream, stacked (the materialized-path cost the 'stream'
        # branch above avoids).
        _, (vs, rs, cs) = jax.lax.scan(step, (b_val, b_idx), None,
                                       length=steps)
        return vb(acc_local)(flat(rs), flat(cs), flat(vs))

    def shard_ring(a_val, a_idx, b_val, b_idx):
        local = rotating_products(a_val, a_idx, b_val, b_idx, perm, n_dev,
                                  a_val.shape[:-2])
        return exchange_tail(local, jnp.int32(0))

    def shard_summa(a_val, a_idx, b_val, b_idx):
        # Logical pr × pc grid over the flat axis: device d = (r, c) with
        # r = d // pc, c = d % pc. Row panel r owns A shard-blocks
        # [r·pc, (r+1)·pc) (contiguous under the 1D slab sharding); column
        # panel c owns B shard-blocks {r'·pc + c} (stride-pc). Cells
        # partition the (A-slab, B-slab) product pairs disjointly, so the
        # exchange tail sees exactly the same global product stream as ring.
        row_perm = [(q * pc + j, q * pc + (j + 1) % pc)
                    for q in range(pr) for j in range(pc)]
        col_perm = [(q * pc + j, ((q + 1) % pr) * pc + j)
                    for q in range(pr) for j in range(pc)]
        # Phase 1 — assemble the grid row's A slab panel: pc−1 neighbour
        # hops along the row ring (a ppermute pipeline, never an
        # all-gather). Panel order doesn't matter: coordinates are absolute
        # and accumulation sorts.
        panels_v, panels_i, av, ai = [a_val], [a_idx], a_val, a_idx
        for _ in range(pc - 1):
            av, ai = rotate(av, ai, row_perm)
            panels_v.append(av)
            panels_i.append(ai)
        panel_val = jnp.concatenate(panels_v, axis=-2)
        panel_idx = jnp.concatenate(panels_i, axis=-2)
        # Phase 2 — rotate B panels pr−1 hops along the column ring, each
        # stage multiplying the full A panel against the visiting B shard.
        local = rotating_products(panel_val, panel_idx, b_val, b_idx,
                                  col_perm, pr, a_val.shape[:-2])
        return exchange_tail(local, jnp.int32(0))

    def shard_cstat(a_val, a_idx, b_val, b_idx):
        me = jax.lax.axis_index(axis)
        lo = me * rpd
        own = (a_idx >= lo) & (a_idx < lo + rpd)
        av = jnp.where(own, a_val, 0)
        ai = jnp.where(own, a_idx, INVALID)
        lead = (a_val.shape[0],) if batched else ()
        if use_stream:
            st0 = streaming.stream_init(streaming.buffer_cap(block_cap),
                                        a_val.dtype, lead=lead)

            def step(carry, _):
                bv, bi, st = carry
                if overlap:
                    nbv, nbi = rotate(bv, bi, perm)
                    v, r, c = _slab_products(av, ai, bv, bi)
                    st = vb(absorb)(st, r, c, v)
                    (nbv, nbi), st = optimization_barrier(((nbv, nbi), st))
                else:
                    v, r, c = _slab_products(av, ai, bv, bi)
                    st = vb(absorb)(st, r, c, v)
                    nbv, nbi = rotate(bv, bi, perm)
                return (nbv, nbi, st), ()
            (_, _, st), _ = jax.lax.scan(step, (b_val, b_idx, st0), None,
                                         length=n_dev)
            blk = vb(partial(streaming.finalize, out_cap=block_cap,
                             n_rows=n_rows, n_cols=n_cols))(st)
            row_b, col_b, val_b, ng_b = blk.row, blk.col, blk.val, blk.ngroups
            poison = (blk.ngroups > block_cap).astype(jnp.int32)
        else:
            buf_r = jnp.full(lead + (block_cap,), INVALID, jnp.int32)
            buf_v = jnp.zeros(lead + (block_cap,), a_val.dtype)
            zero = jnp.zeros(lead, jnp.int32)

            def step(carry, _):
                bv, bi, row_b, col_b, val_b, ng, poison = carry
                if overlap:
                    nbv, nbi = rotate(bv, bi, perm)
                v, r, c = _slab_products(av, ai, bv, bi)
                sq = lambda x: x.reshape(lead + (-1,))
                blk = vb(merge_step)(
                    jnp.concatenate([row_b, sq(r)], axis=-1),
                    jnp.concatenate([col_b, sq(c)], axis=-1),
                    jnp.concatenate([val_b, sq(v)], axis=-1))
                poison = poison + (blk.ngroups > block_cap).astype(jnp.int32)
                if overlap:
                    (nbv, nbi), poison = optimization_barrier(
                        ((nbv, nbi), poison))
                else:
                    nbv, nbi = rotate(bv, bi, perm)
                return (nbv, nbi, blk.row, blk.col, blk.val, blk.ngroups,
                        poison), ()
            (_, _, row_b, col_b, val_b, ng_b, poison), _ = jax.lax.scan(
                step, (b_val, b_idx, buf_r, buf_r, buf_v, zero, zero), None,
                length=n_dev)
        ng = (jax.lax.psum(ng_b, axis)
              + jnp.where(jax.lax.psum(poison, axis) > 0,
                          jnp.int32(out_cap + 1), jnp.int32(0)))
        return row_b[None], col_b[None], val_b[None], ng

    from repro.parallel.sharding import spgemm_operand_specs
    spec_a, spec_b = spgemm_operand_specs(axis, schedule=sched,
                                          batched=batched)
    blk_spec = P(axis, *([None] * (1 + int(batched))))
    body = {"ring": shard_ring, "cstat": shard_cstat,
            "summa": shard_summa}[sched]
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(spec_a, spec_a, spec_b, spec_b),
        out_specs=(blk_spec, blk_spec, blk_spec, P()))
    if _obs.is_enabled():
        # per-step spans can't escape the shard_map/scan body (it traces
        # once), so the exchange is observed at the dispatch boundary with
        # the DistPlan's modeled per-device comm bytes attached
        comm = float(dp.est.get(f"{sched}_comm_bytes", 0.0))
        steps = (pc - 1) + pr if sched == "summa" else n_dev
        span_kw = dict(schedule=sched, backend=backend, n_dev=n_dev,
                       steps=steps, overlap=overlap,
                       comm_bytes_per_dev=comm)
        if sched == "summa":
            span_kw["grid"] = f"{pr}x{pc}"
        with _obs.span("dist.exchange", **span_kw) as _sp:
            row_g, col_g, val_g, ngroups = fn(a.val, a.idx, b.val, b.idx)
            _obs.sync(val_g)
        _obs_metrics.inc(f"dist.comm_bytes.{sched}", comm * n_dev)
        _obs_metrics.inc("dist.calls")
        if overlap:
            # modeled fraction of the rotation traffic that fits under the
            # device-local accumulation (12 B/product read-modify-write):
            # 1.0 = the exchange hides entirely behind compute
            work = 12.0 * float(dp.est.get("flops", 0.0)) / max(1, n_dev)
            _obs_metrics.gauge(
                "dist.overlap_efficiency",
                1.0 if comm <= 0 else min(1.0, work / comm))
    else:
        row_g, col_g, val_g, ngroups = fn(a.val, a.idx, b.val, b.idx)
    compact = partial(_compact_sorted, out_cap=out_cap,
                      shape=(n_rows, n_cols))
    if batched:
        coo = jax.vmap(lambda r, c, v, g: compact(r, c, v, ngroups=g))(
            flat(row_g), flat(col_g), flat(val_g), ngroups)
    else:
        coo = compact(flat(row_g), flat(col_g), flat(val_g), ngroups=ngroups)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


def spgemm_coo_sharded_batched(a: EllRows, b: EllCols, mesh: Mesh, axis: str,
                               *, dist_plan, schedule: str = "auto",
                               overlap: bool = True,
                               check: bool = False) -> Coo:
    """Batched sharded SpGEMM: ELLPACK planes carry a leading batch axis
    (shared shapes/caps across the batch). Prefer ``repro.spgemm(a, b,
    mesh=mesh, axis=axis, dist_plan=dp)`` — the unified front door detects
    the batch axis and delegates here. Requires a ``dist_plan`` built
    with ``plan.make_dist_plan`` on a representative slice — 'auto' planning
    inspects operand values, which a batch makes ambiguous. Returns a
    ``Coo`` whose leaves (including ``ngroups``) lead with the batch axis.
    """
    if a.val.ndim != 3 or b.val.ndim != 3:
        raise ValueError("batched operands need a leading batch axis on all "
                         f"ELLPACK planes; got A {a.val.ndim}D, B {b.val.ndim}D")
    return spgemm_coo_sharded(a, b, mesh, axis, dist_plan=dist_plan,
                              schedule=schedule, overlap=overlap,
                              check=check)


def spgemm_coo_sharded_numeric(a: EllRows, b: EllCols, mesh: Mesh, axis: str,
                               structure, *, schedule: str = "auto",
                               overlap: bool = True, check: bool = False,
                               validate: bool = True) -> Coo:
    """Distributed numeric phase: rotate B slabs (1D ring or 2D summa grid),
    binary-search each
    step's slab products into the precomputed structure slots, ``psum`` the
    slot accumulators. Prefer ``repro.spgemm(a, b, mesh=mesh, axis=axis,
    structure=st)`` — the unified front door delegates here. No planning, no device-local sort, no owner-binned
    COO exchange — the only cross-device traffic is the operand rotation plus
    one ``(out_cap + 1)`` accumulator reduction, and the per-device peak
    intermediate is a single slab-pair product tile plus that accumulator.

    ``schedule`` accepts ``'auto'`` (the structure's cached DistPlan pick
    when one exists and it chose ``'summa'``, else ``'ring'``), ``'ring'``,
    or ``'summa'`` (2D grid operand motion; the final reduction stays one
    psum). ``'cstat'`` has no meaning here — there is no resident C block —
    and raises. ``overlap=True`` applies the same prefetch-before-accumulate
    double-buffering as the cold path; numerics are unaffected.

    ``structure`` comes from ``plan.make_structure`` on the same (global,
    unbatched) operands; it does **not** need ``n_dev`` — the slot scatter
    replaces the DistPlan machinery entirely (cold repeat calls that still
    want the exchange pipeline reuse cached DistPlans via
    ``spgemm_coo_sharded(..., structure=)`` instead). Output is replicated
    sorted COO, the same contract as ``spgemm_coo_sharded``, equal to the
    cold result up to floating-point summation order."""
    if validate:
        structure.validate(a, b)
    if a.val.ndim != 2:
        raise ValueError("spgemm_coo_sharded_numeric is unbatched — vmap "
                         "spgemm_coo_numeric for batched operands")
    st = structure
    n_dev = mesh.shape[axis]
    if schedule not in ("auto", "ring", "summa"):
        raise ValueError(
            f"unknown numeric-path schedule {schedule!r} — the warm numeric "
            "phase supports 'auto', 'ring', or 'summa' (no resident C block, "
            "so 'cstat' does not apply)")
    sched, pr, pc = schedule, 1, 1
    cached = None
    if st.dist_plans:
        dp = st.dist_plan(None)
        if dp.n_dev == n_dev:
            cached = dp
    if sched == "auto":
        sched = ("summa" if cached is not None and cached.schedule == "summa"
                 else "ring")
    if sched == "summa":
        if cached is not None and cached.pr * cached.pc == n_dev:
            pr, pc = cached.pr, cached.pc
        else:
            from repro.plan.planner import best_grid
            pr, pc = best_grid(n_dev, a.val.shape[-2], b.val.shape[-1],
                               allow_degenerate=True)
    a = pad_slabs_a(a, n_dev)
    b = pad_slabs_b(b, n_dev)
    n_rows, n_cols, out_cap = st.n_rows, st.n_cols, st.out_cap
    ring_perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
    row_perm = [(q * pc + j, q * pc + (j + 1) % pc)
                for q in range(pr) for j in range(pc)]
    col_perm = [(q * pc + j, ((q + 1) % pr) * pc + j)
                for q in range(pr) for j in range(pc)]
    acc_dtype = jnp.result_type(a.val.dtype, b.val.dtype)

    def shard_fn(a_val, a_idx, b_val, b_idx, key):
        if sched == "summa":
            # assemble the grid row's A slab panel (pc−1 row-ring hops),
            # then rotate B along the column ring — same 2D stage structure
            # as the cold path, minus the exchange tail
            pv, pi, av, ai = [a_val], [a_idx], a_val, a_idx
            for _ in range(pc - 1):
                av = jax.lax.ppermute(av, axis, row_perm)
                ai = jax.lax.ppermute(ai, axis, row_perm)
                pv.append(av)
                pi.append(ai)
            res_val = jnp.concatenate(pv, axis=-2)
            res_idx = jnp.concatenate(pi, axis=-2)
            perm, steps = col_perm, pr
        else:
            res_val, res_idx, perm, steps = a_val, a_idx, ring_perm, n_dev

        def absorb(acc, nm, bv, bi):
            v, r, c = _slab_products(res_val, res_idx, bv, bi)
            v, r, c = v.reshape(-1), r.reshape(-1), c.reshape(-1)
            valid = r >= 0
            pk = jnp.where(valid, r * n_cols + c, 0).astype(jnp.int32)
            slot = jnp.searchsorted(key, pk, side="left").astype(jnp.int32)
            miss = jnp.logical_or(
                ~valid, jnp.take(key, jnp.minimum(slot, out_cap - 1),
                                 mode="clip") != pk)
            slot = jnp.where(miss, out_cap, slot)
            # valid products missing from the structure lose their value to
            # the dump slot — counted and psum'd so the result is poisoned
            nm = nm + jnp.sum(jnp.logical_and(valid, miss)).astype(jnp.int32)
            acc = acc + jax.ops.segment_sum(jnp.where(valid, v, 0), slot,
                                            num_segments=out_cap + 1)
            return acc, nm

        def step(carry, _):
            bv, bi, acc, nm = carry
            if overlap:
                nbv = jax.lax.ppermute(bv, axis, perm)
                nbi = jax.lax.ppermute(bi, axis, perm)
                acc, nm = absorb(acc, nm, bv, bi)
                (nbv, nbi), (acc, nm) = optimization_barrier(
                    ((nbv, nbi), (acc, nm)))
            else:
                acc, nm = absorb(acc, nm, bv, bi)
                nbv = jax.lax.ppermute(bv, axis, perm)
                nbi = jax.lax.ppermute(bi, axis, perm)
            return (nbv, nbi, acc, nm), ()

        init = (b_val, b_idx,
                pvary(jnp.zeros((out_cap + 1,), acc_dtype), axis),
                pvary(jnp.zeros((), jnp.int32), axis))
        (_, _, acc, nm), _ = jax.lax.scan(step, init, None, length=steps)
        return jax.lax.psum(acc, axis), jax.lax.psum(nm, axis)

    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(axis, None), P(axis, None),
                             P(None, axis), P(None, axis), P()),
                   out_specs=(P(), P()))
    sums, n_miss = fn(a.val, a.idx, b.val, b.idx, st.key)
    from .spgemm import _coo_from_slots, _poison_overflow
    coo = _coo_from_slots(st.key, sums[:out_cap], st.nnz, out_cap=out_cap,
                          n_rows=n_rows, n_cols=n_cols)
    coo = _poison_overflow(coo, n_miss)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


# ---------------------------------------------------------------------------
# Dense-psum baseline (what the sparse path replaces) + ring collective
# ---------------------------------------------------------------------------

def _local_multiply_accumulate(a_val, a_idx, b_val, b_idx, n_rows, n_cols, c_acc):
    """One ring step: resident A slabs × visiting B slabs → dense partial C."""
    val, row, col = _slab_products(a_val, a_idx, b_val, b_idx)
    return c_acc + scatter_dense(row, col, val, n_rows, n_cols)


def ring_spgemm(a: EllRows, b: EllCols, mesh: Mesh, axis: str) -> jax.Array:
    """C = A·B with slabs sharded over ``axis`` and B-slabs ring-rotated.

    The **dense baseline**: every device scatters partials into a dense
    per-device C and a final ``psum`` merges them — per-device partial
    memory is O(n_rows·n_cols) regardless of sparsity, which is exactly the
    scaling failure ``spgemm_coo_sharded`` exists to fix (its partials stay
    COO and scale ~1/devices). Kept for verification and as the measured
    baseline of the distributed benchmark suite.

    Slab counts that don't divide the ring size are padded with INVALID
    lanes (``pad_slabs_a``/``pad_slabs_b``) rather than rejected.
    """
    n_dev = mesh.shape[axis]
    a = pad_slabs_a(a, n_dev)
    b = pad_slabs_b(b, n_dev)
    n_rows, n_cols = a.n_rows, b.n_cols

    def shard_fn(a_val, a_idx, b_val, b_idx):
        def step(carry, _):
            b_val_c, b_idx_c, c_acc = carry
            c_acc = _local_multiply_accumulate(
                a_val, a_idx, b_val_c, b_idx_c, n_rows, n_cols, c_acc)
            # ring-rotate the visiting B slabs to the next device (RowClone)
            perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
            b_val_c = jax.lax.ppermute(b_val_c, axis, perm)
            b_idx_c = jax.lax.ppermute(b_idx_c, axis, perm)
            return (b_val_c, b_idx_c, c_acc), ()

        init = (b_val, b_idx,
                pvary(jnp.zeros((n_rows, n_cols), a_val.dtype), axis))
        (b_val, b_idx, c_acc), _ = jax.lax.scan(step, init, None, length=n_dev)
        return jax.lax.psum(c_acc, axis)

    spec_a = P(axis, None)
    spec_b = P(None, axis)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(spec_a, spec_a, spec_b, spec_b),
        out_specs=P())
    return fn(a.val, a.idx, b.val, b.idx)


def ring_all_to_all(x: jax.Array, axis: str) -> jax.Array:
    """SPLIM-style ring alternative to ``all_to_all`` (inside shard_map).

    ``x``: (n_dev, chunk, ...) — chunk i is destined for device i. Rotates
    the whole buffer around the ring, each device peeling off its chunk; uses
    n_dev-1 ppermutes of shrinking usefulness but only neighbour links (no
    global crossbar pressure), matching the paper's C/A-conflict-free
    RowClone argument. Used by MoE when ``moe_comm='ring'`` and by the
    B-stationary schedule's owner-binned COO exchange.
    """
    n_dev = axis_size(axis)
    me = jax.lax.axis_index(axis)
    out = jnp.zeros_like(x)
    out = out.at[me].set(x[me])

    def step(carry, i):
        buf, out = carry
        perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
        buf = jax.lax.ppermute(buf, axis, perm)
        src = (me - i - 1) % n_dev          # whose buffer is visiting now
        out = out.at[src].set(buf[me])
        return (buf, out), ()

    (x, out), _ = jax.lax.scan(step, (x, out), jnp.arange(n_dev - 1))
    return out
