"""Distributed SPLIM SpGEMM — the paper's ring broadcast on the ICI torus.

Paper Fig. 6(c): B column-vectors rotate array→array (2-step RowClone) while
A row-vectors stay put; every array multiplies its resident A slabs against
the visiting B slabs; intermediate results never cross arrays (§VI-D:
"SPLIM circumvents the need for cross-PE transfer of intermediate results").

TPU mapping: the array ring is a mesh-axis ring, RowClone is
``jax.lax.ppermute`` (one ICI hop, no shared-bus conflicts at all — stronger
than the paper's 2-phase odd/even RowClone schedule), and the per-array
multiply is the SCCP slab product. The final accumulate stays device-local
(scatter into a per-device partial C) and a single ``psum`` at the end plays
the role of the paper's off-chip COO merge.

The same ring schedule is reused by the LM stack for MoE token exchange
(models/moe.py, ``ring_all_to_all``) — SPLIM's communication pattern promoted
to a first-class collective strategy.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, pvary, shard_map

from .accumulate import scatter_dense
from .formats import EllCols, EllRows, INVALID


def _local_multiply_accumulate(a_val, a_idx, b_val, b_idx, n_rows, n_cols, c_acc):
    """One ring step: resident A slabs × visiting B slabs → dense partial C."""
    val = a_val[:, :, None] * b_val[None, :, :]            # (ka_loc, n, kb_loc)
    row = jnp.broadcast_to(a_idx[:, :, None], val.shape)
    col = jnp.broadcast_to(b_idx[None, :, :], val.shape)
    ok = (row >= 0) & (col >= 0)
    val = jnp.where(ok, val, 0)
    row = jnp.where(ok, row, INVALID)
    col = jnp.where(ok, col, INVALID)
    return c_acc + scatter_dense(row, col, val, n_rows, n_cols)


def ring_spgemm(a: EllRows, b: EllCols, mesh: Mesh, axis: str) -> jax.Array:
    """C = A·B with slabs sharded over ``axis`` and B-slabs ring-rotated.

    A.val/idx: (k_a, n) sharded on dim 0; B.val/idx: (n, k_b) sharded on
    dim 1. Returns dense C replicated (psum-merged), the verifiable analogue
    of the paper's off-chip COO merge.
    """
    n_dev = mesh.shape[axis]
    n_rows, n_cols = a.n_rows, b.n_cols
    if a.k % n_dev or b.k % n_dev:
        raise ValueError(f"slab counts ({a.k},{b.k}) must divide ring size {n_dev}")

    def shard_fn(a_val, a_idx, b_val, b_idx):
        me = jax.lax.axis_index(axis)

        def step(carry, _):
            b_val_c, b_idx_c, c_acc = carry
            c_acc = _local_multiply_accumulate(
                a_val, a_idx, b_val_c, b_idx_c, n_rows, n_cols, c_acc)
            # ring-rotate the visiting B slabs to the next device (RowClone)
            perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
            b_val_c = jax.lax.ppermute(b_val_c, axis, perm)
            b_idx_c = jax.lax.ppermute(b_idx_c, axis, perm)
            return (b_val_c, b_idx_c, c_acc), ()

        init = (b_val, b_idx,
                pvary(jnp.zeros((n_rows, n_cols), a_val.dtype), axis))
        (b_val, b_idx, c_acc), _ = jax.lax.scan(step, init, None, length=n_dev)
        del me
        return jax.lax.psum(c_acc, axis)

    spec_a = P(axis, None)
    spec_b = P(None, axis)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(spec_a, spec_a, spec_b, spec_b),
        out_specs=P())
    return fn(a.val, a.idx, b.val, b.idx)


def ring_all_to_all(x: jax.Array, axis: str) -> jax.Array:
    """SPLIM-style ring alternative to ``all_to_all`` (inside shard_map).

    ``x``: (n_dev, chunk, ...) — chunk i is destined for device i. Rotates
    the whole buffer around the ring, each device peeling off its chunk; uses
    n_dev-1 ppermutes of shrinking usefulness but only neighbour links (no
    global crossbar pressure), matching the paper's C/A-conflict-free
    RowClone argument. Used by MoE when ``moe_comm='ring'``.
    """
    n_dev = axis_size(axis)
    me = jax.lax.axis_index(axis)
    out = jnp.zeros_like(x)
    out = out.at[me].set(x[me])

    def step(carry, i):
        buf, out = carry
        perm = [(d, (d + 1) % n_dev) for d in range(n_dev)]
        buf = jax.lax.ppermute(buf, axis, perm)
        src = (me - i - 1) % n_dev          # whose buffer is visiting now
        out = out.at[src].set(buf[me])
        return (buf, out), ()

    (x, out), _ = jax.lax.scan(step, (x, out), jnp.arange(n_dev - 1))
    return out
