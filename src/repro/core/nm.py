"""N:M balanced-sparsity weight format (nmSPARSE-style condensed planes).

A weight W (d_in, d_out) is *N:M balanced along the reduction dimension*
when every M consecutive entries of each column hold at most N non-zeros.
The condensed storage keeps, per window and column, exactly N slots:

  * ``val`` (R, d_out) — dense value planes, R = d_in · N / M rows
  * ``off`` (R, d_out) — the within-window offset of each kept value,
    an int8 plane whose payload is only ⌈log2 M⌉ bits (nmSPARSE's index
    planes; int8 is the narrowest container JAX ships)

Row r of the planes belongs to window ``r // N``; the original row of
``val[r, j]`` is ``(r // N) · M + off[r, j]``. Windows with fewer than N
non-zeros pad with val = 0 and a distinct unused offset, so offsets stay a
partial permutation of the window and the structural N-per-window invariant
holds unconditionally — that balance is what lets kernels/nm_spmm.py stay
gather-free and perfectly load-balanced (vs. ELLPACK/COO, where slab width
follows the worst row).

``detect_nm`` is the planner-facing check: models route a pruned weight to
this format when a candidate (N, M) matches (plan.planner.plan_spmm_format).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# candidate windows probed by auto-detection, most structured first
NM_CANDIDATES: Tuple[Tuple[int, int], ...] = ((1, 4), (2, 4), (2, 8), (4, 8))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class NmWeights:
    """Condensed N:M weight (right operand of x @ W). val/off: (R, d_out)."""

    val: jax.Array  # (R, d_out) float, condensed value planes
    off: jax.Array  # (R, d_out) int8, within-window offsets in [0, m)
    n: int
    m: int
    d_in: int       # logical reduction dim (= R * m / n)

    def tree_flatten(self):
        return (self.val, self.off), (self.n, self.m, self.d_in)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], *aux)

    @property
    def d_out(self) -> int:
        return self.val.shape[1]

    @property
    def r(self) -> int:
        return self.val.shape[0]

    @property
    def windows(self) -> int:
        return self.d_in // self.m

    def to_dense(self) -> jax.Array:
        """Scatter back to (d_in, d_out). Oracle/debug only."""
        r, d_out = self.val.shape
        win = jnp.arange(r, dtype=jnp.int32) // self.n
        rows = win[:, None] * self.m + self.off.astype(jnp.int32)
        cols = jnp.broadcast_to(jnp.arange(d_out, dtype=jnp.int32),
                                (r, d_out))
        dense = jnp.zeros((self.d_in, d_out), self.val.dtype)
        # offsets are distinct per (window, col); pad slots add 0
        return dense.at[rows.reshape(-1), cols.reshape(-1)].add(
            self.val.reshape(-1))


def nm_from_dense(w: jax.Array, n: int, m: int) -> NmWeights:
    """Condense a dense (d_in, d_out) N:M-balanced weight.

    Raises if some window holds more than N non-zeros (the pattern is not
    N:M — prune first with models.sparse.magnitude_prune_nm).
    """
    d_in, d_out = w.shape
    if d_in % m:
        raise ValueError(f"d_in={d_in} not a multiple of M={m}")
    ww = w.reshape(d_in // m, m, d_out)
    counts = (ww != 0).sum(axis=1)
    if int(jnp.max(counts)) > n:
        raise ValueError(
            f"pattern is not {n}:{m} balanced (window with "
            f"{int(jnp.max(counts))} non-zeros)")
    # stable argsort pushes zeros last: the first N offsets per window are
    # the non-zeros (in original order), the rest point at zero slots —
    # a partial permutation, so gathering values pads with exact 0s
    order = jnp.argsort(ww == 0, axis=1, stable=True)[:, :n, :]
    vals = jnp.take_along_axis(ww, order, axis=1)
    return NmWeights(
        val=vals.reshape(-1, d_out),
        off=order.astype(jnp.int8).reshape(-1, d_out),
        n=n, m=m, d_in=d_in)


def is_nm_balanced(w: jax.Array, n: int, m: int) -> bool:
    """True iff every M-window of every column has ≤ N non-zeros."""
    d_in = w.shape[0]
    if d_in % m:
        return False
    counts = (w.reshape(d_in // m, m, -1) != 0).sum(axis=1)
    return bool(jnp.max(counts) <= n)


def detect_nm(w: jax.Array,
              candidates: Sequence[Tuple[int, int]] = NM_CANDIDATES,
              ) -> Optional[Tuple[int, int]]:
    """First candidate (N, M) the pattern satisfies, or None.

    Candidates are probed in order (most structured first) and only count
    when N < M — an N:N window is dense and buys nothing. A dense weight
    matches no candidate, so callers fall back to ELLPACK/COO.
    """
    for n, m in candidates:
        if n < m and is_nm_balanced(w, n, m):
            return (n, m)
    return None
