"""The unified SpGEMM front door: one ``spgemm()`` for every variant.

The SpGEMM surface grew to ~12 entry points across ``core/spgemm.py``,
``core/streaming.py`` and ``core/distributed.py`` (cold / numeric / batched
/ streaming / sharded crosses). They all remain as thin, stable wrappers,
but ``repro.spgemm(a, b, ...)`` is the preferred spelling: it dispatches on
*what you hand it* — a prebuilt structure routes to the warm numeric phase,
a mesh+axis to the sharded path, 3-D operand planes to the vmapped batched
variants — so call sites never hard-code a variant name.

Auto-select semantics, in one place (every wrapper follows these rules):

``out_cap``
    Static output capacity. ``"auto"`` (default everywhere, including the
    stream path) runs the symbolic phase via ``plan.make_plan`` on concrete
    operands; under jit/vmap pass an int or a prebuilt ``plan=``.
``accumulator``
    Accumulation backend: ``'sort' | 'tiled' | 'bucket' | 'hash' | 'stream'
    | 'search'``. ``None`` defaults to ``'sort'``; only an explicit
    ``'auto'`` (or a ``plan=`` / ``structure=``) opts into the planner's
    cost-model choice. ``'stream'`` is the only backend that never
    materializes the product stream.
``schedule``
    Distributed schedules (mesh paths only): ``'ring'`` (B-stationary) |
    ``'cstat'`` (C-stationary) | ``'summa'`` (communication-avoiding 2D
    grid). ``"auto"`` lets ``plan.make_dist_plan``
    weigh the per-device communication volume (including the 2D grid's).
``overlap``
    Mesh paths only: ``True`` (default) double-buffers operand rotation —
    each stage's ``ppermute`` prefetch is issued before the current stage's
    accumulation and rejoined with ``compat.optimization_barrier``, hiding
    communication behind compute. Bit-identical either way.
``interpret`` / kernel mode
    Pallas kernels resolve via ``kernels.bitonic_merge.resolve_mode``:
    ``None`` → compiled on TPU, XLA realization elsewhere; ``True`` forces
    the interpreter (debug), ``False`` forces compiled Pallas.
``batched``
    ``"auto"`` (default) detects a leading batch axis on the ELLPACK value
    planes (``a.val.ndim == 3``); ``True``/``False`` force it.

Warm-path contract: pass ``structure=`` (from ``plan.make_structure`` /
``plan.cache.StructureCache.get``) and only the numeric phase runs —
coordinates are never re-sorted, and misses against the frozen pattern
poison ``ngroups`` exactly like accumulator overflow (``check=True`` or
``core.check_no_overflow`` to raise).
"""
from __future__ import annotations

from typing import Optional

from .formats import Coo, EllCols, EllRows


def spgemm(a: EllRows, b: EllCols, *, structure=None, mesh=None,
           axis: Optional[str] = None, batched="auto", out_cap="auto",
           accumulator: Optional[str] = None, schedule: str = "auto",
           tile: Optional[int] = None, plan=None, dist_plan=None,
           overlap: bool = True, stream_cap: Optional[int] = None,
           group: Optional[int] = None, check: bool = False,
           validate: bool = True) -> Coo:
    """C = A·B as sorted COO — dispatches to the right SpGEMM variant.

    Routing (first match wins):

    * ``mesh``/``axis`` set → the sharded paths (``core.distributed``):
      with ``structure`` the device-local numeric phase
      (``spgemm_coo_sharded_numeric``; batched structures route through
      ``spgemm_coo_sharded`` with the structure's cached dist plan),
      otherwise the cold ``spgemm_coo_sharded`` (``schedule``/``dist_plan``
      select the exchange schedule).
    * ``structure`` set → warm numeric phase (``spgemm_coo_numeric`` /
      ``_numeric_batched``); stream-planned structures take the slab-scan
      numeric realization automatically.
    * otherwise → cold single-device path (``spgemm_coo`` /
      ``spgemm_coo_batched``); ``accumulator='stream'`` with explicit
      ``stream_cap``/``group`` routes through ``spgemm_coo_stream``.

    Kwargs not consumed by the selected variant (e.g. ``schedule`` without a
    mesh) are ignored only when they hold their defaults; see the module
    docstring for the shared auto-select semantics.
    """
    if axis is not None and mesh is None:
        raise ValueError("axis= requires mesh= (a jax.sharding.Mesh)")
    if mesh is not None and axis is None:
        raise ValueError("mesh= requires axis= (the mesh axis name)")
    if batched == "auto":
        is_batched = a.val.ndim == 3
    else:
        is_batched = bool(batched)
        if is_batched and a.val.ndim != 3:
            raise ValueError("batched=True needs 3-D ELLPACK planes "
                             f"(got a.val.ndim={a.val.ndim})")

    if mesh is not None:
        from .distributed import (spgemm_coo_sharded,
                                  spgemm_coo_sharded_batched,
                                  spgemm_coo_sharded_numeric)
        if structure is not None and not is_batched:
            return spgemm_coo_sharded_numeric(a, b, mesh, axis, structure,
                                              schedule=schedule,
                                              overlap=overlap,
                                              check=check, validate=validate)
        if is_batched and structure is None and dist_plan is not None:
            return spgemm_coo_sharded_batched(a, b, mesh, axis,
                                              dist_plan=dist_plan,
                                              schedule=schedule,
                                              overlap=overlap,
                                              check=check)
        return spgemm_coo_sharded(a, b, mesh, axis, out_cap,
                                  accumulator=accumulator or "auto",
                                  schedule=schedule, dist_plan=dist_plan,
                                  structure=structure, overlap=overlap,
                                  check=check)

    if structure is not None:
        from .spgemm import spgemm_coo_numeric, spgemm_coo_numeric_batched
        if is_batched:
            return spgemm_coo_numeric_batched(a, b, structure, check=check,
                                              validate=validate)
        return spgemm_coo_numeric(a, b, structure, check=check,
                                  validate=validate)

    if accumulator == "stream" and (stream_cap is not None
                                    or group is not None):
        from .streaming import spgemm_coo_stream
        if is_batched:
            raise ValueError("batched stream SpGEMM: pass a plan= built "
                             "with backend='stream' instead of explicit "
                             "stream_cap/group")
        return spgemm_coo_stream(a, b, out_cap, stream_cap=stream_cap,
                                 group=group)

    from .spgemm import spgemm_coo, spgemm_coo_batched
    fn = spgemm_coo_batched if is_batched else spgemm_coo
    return fn(a, b, out_cap, accumulator=accumulator, tile=tile,
              check=check, plan=plan)
