"""Hybrid ELLPACK + COO format (paper §III-C, Fig. 12).

Rows/columns whose non-zero count exceeds ``NNZ-a + σ`` (mean + one stddev)
would inflate the ELLPACK width ``k`` for everyone; their overflow beyond the
threshold is diverted to a COO side structure. ELL-PEs process the condensed
part with SCCP; COO-PEs process the remainder "following the procedure of
Fig. 5" — i.e. decompression against the other operand (paper §IV-B). We keep
that split faithfully: the COO partial products are computed against the
*densified* other operand, exactly the paper's COO-PE dataflow.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .formats import (Coo, EllCols, EllRows, coo_from_dense,
                      ell_cols_from_dense, ell_rows_from_dense)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HybridRows:
    """Row-wise hybrid for the left matrix: ELLPACK trunk + COO overflow."""

    ell: EllRows
    coo: Coo

    def tree_flatten(self):
        return (self.ell, self.coo), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    def to_dense(self) -> jax.Array:
        return self.ell.to_dense() + self.coo.to_dense()


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class HybridCols:
    ell: EllCols
    coo: Coo

    def tree_flatten(self):
        return (self.ell, self.coo), ()

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    def to_dense(self) -> jax.Array:
        return self.ell.to_dense() + self.coo.to_dense()


def ell_width_rule(nnz_per_lane: np.ndarray) -> int:
    """Paper's boundary: k = ceil(mean + std) of per-lane non-zero counts."""
    nnz_av = float(np.mean(nnz_per_lane))
    sigma = float(np.std(nnz_per_lane))
    return max(1, int(np.ceil(nnz_av + sigma)))


def split_rows_hybrid(a: jax.Array, k: int, coo_cap: int) -> HybridRows:
    """Left matrix: first k non-zeros of each *column* into ELLPACK, rest COO."""
    ell = ell_rows_from_dense(a, k)
    trunk = ell.to_dense()
    overflow = a - trunk
    return HybridRows(ell=ell, coo=coo_from_dense(overflow, coo_cap))


def split_cols_hybrid(b: jax.Array, k: int, coo_cap: int) -> HybridCols:
    """Right matrix: first k non-zeros of each *row* into ELLPACK, rest COO."""
    ell = ell_cols_from_dense(b, k)
    trunk = ell.to_dense()
    overflow = b - trunk
    return HybridCols(ell=ell, coo=coo_from_dense(overflow, coo_cap))


def _coo_matmul_dense(coo: Coo, other_dense: jax.Array, left: bool) -> jax.Array:
    """COO-PE path: partial products of a COO operand against the densified
    other operand (paper Fig. 5 procedure). left=True → coo is the A part."""
    m, n = coo.shape
    ok = coo.valid_mask()
    if left:
        # C[r, :] += v * B[c, :]
        rows = jnp.where(ok, coo.row, m)
        gathered = other_dense[jnp.where(ok, coo.col, 0)]          # (cap, n_out)
        contrib = jnp.where(ok[:, None], coo.val[:, None] * gathered, 0)
        out = jnp.zeros((m + 1, other_dense.shape[1]), contrib.dtype)
        return out.at[rows].add(contrib)[:m]
    else:
        # C[:, c] += A[:, r] * v
        cols = jnp.where(ok, coo.col, n)
        gathered = other_dense[:, jnp.where(ok, coo.row, 0)]        # (n_out, cap)
        contrib = jnp.where(ok[None, :], gathered * coo.val[None, :], 0)
        out = jnp.zeros((other_dense.shape[0], n + 1), contrib.dtype)
        return out.at[:, cols].add(contrib)[:, :n]


def hybrid_spgemm_dense(a: HybridRows, b: HybridCols) -> jax.Array:
    """Full hybrid SpGEMM (dense output): ELL×ELL via SCCP + three COO-PE terms."""
    from .spgemm import spgemm_dense  # local import to avoid cycle

    c = spgemm_dense(a.ell, b.ell)                              # ELL-PEs (SCCP)
    b_dense = b.to_dense()
    a_ell_dense = a.ell.to_dense()
    c = c + _coo_matmul_dense(a.coo, b_dense, left=True)        # COO_A × (all of B)
    c = c + _coo_matmul_dense(b.coo, a_ell_dense, left=False)   # ELL_A × COO_B
    return c
