"""Analytical PUM cost model (paper Table II + §III analyses).

This container has no ReRAM, so the paper's latency / energy claims (Figs.
14-19) are reproduced with a first-principles model of the SPLIM hardware,
parameterized by the paper's published configuration, plus proxy models for
the comparison platforms. Per-matrix *variation* is fully determined by the
matrix statistics flowing through the model; the absolute scale of each
comparison platform is anchored once (single scalar per platform) to the
paper's reported fleet-mean so that headline ratios are reproduced honestly —
the calibration is declared here and in EXPERIMENTS.md §Paper-validation.

All latencies in seconds, energies in joules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class SplimConfig:
    """Paper Table II / §V 'SPLIM configurations'."""

    n_pes: int = 32
    arrays_per_pe: int = 1000
    array_rows: int = 1024
    array_cols: int = 1024
    cells_per_f32: int = 32          # 32 memristor cells per float32
    freq_hz: float = 1.0e9           # 1 GHz 1T1M
    # Digital in-situ fp32 arithmetic, FloatPIM-style NOR sequences:
    mult_cycles: float = 1484.0      # bit-serial fp32 multiply, per slab pair
    add_cycles: float = 384.0        # bit-serial fp32 add
    search_cycles_per_bit: float = 1.0   # Alg. 1: one column scan per bit
    rowclone_cycles: float = 100.0   # per 1024-lane segment hop
    oci_bw: float = 1000e9           # 1000 GB/s on-chip interconnect [43]
    # Power (Table II, per PE unless noted)
    array_power_w: float = 6.14      # "6.14K mW" ReRAM arrays per PE
    buffer_power_w: float = 0.0794
    acc_power_w: float = 0.0002
    ctrl_power_w: float = 0.2078     # one controller for the chip
    io_energy_per_byte: float = 4e-12

    @property
    def vectors_per_array(self) -> int:
        return self.array_cols // self.cells_per_f32   # 32 f32 vectors

    @property
    def lanes_total(self) -> int:
        return self.n_pes * self.arrays_per_pe * self.array_rows


@dataclasses.dataclass(frozen=True)
class MatrixStats:
    """Everything the cost models need about one SpGEMM problem C = A·B."""

    n: int                 # dimension (square)
    nnz_a: int
    nnz_b: int
    k_a: int               # ELLPACK widths after the hybrid rule
    k_b: int
    valid_products: int    # Σ_c nnzcol_A(c)·nnzrow_B(c)  (paper's NK²)
    nnz_c: int             # unique output coordinates
    sigma: float           # stddev of per-row nnz (Table I)

    @property
    def flops(self) -> int:
        return 2 * self.valid_products


def stats_from_scipy(a, b) -> MatrixStats:
    """Exact stats from scipy sparse operands (host-side)."""
    import scipy.sparse as sp
    a = a.tocsc(); b = b.tocsr()
    col_nnz_a = np.diff(a.indptr)
    row_nnz_b = np.diff(b.indptr)
    valid = int(np.sum(col_nnz_a.astype(np.int64) * row_nnz_b.astype(np.int64)))
    row_nnz_a = np.diff(a.tocsr().indptr)
    k_a = max(1, int(np.ceil(col_nnz_a.mean() + col_nnz_a.std())))
    k_b = max(1, int(np.ceil(row_nnz_b.mean() + row_nnz_b.std())))
    c = (a.tocsr() @ b).tocsr()
    return MatrixStats(n=a.shape[0], nnz_a=a.nnz, nnz_b=b.nnz, k_a=k_a, k_b=k_b,
                       valid_products=valid, nnz_c=c.nnz,
                       sigma=float(row_nnz_a.std()))


def stats_from_ell(a, b, nnz_c: int | None = None) -> MatrixStats:
    """``stats_from_scipy``'s device-side twin: stats from ELLPACK operands.

    Works on the same ``EllRows``/``EllCols`` pair the SpGEMM entry points
    consume — no scipy round-trip, no dense C. Every field is reduced with
    jnp ops (so the arrays can live on device) and pulled back as Python
    ints at the end; call with *concrete* operands (it is a planning step,
    like ``plan.make_plan`` which feeds it the exact ``nnz_c`` from the
    symbolic pass). ``nnz_c=None`` falls back to the row-flop upper bound.
    """
    import jax
    import jax.numpy as jnp
    a_ok = a.valid_mask()                  # (k_a, n)
    b_ok = b.valid_mask()                  # (n, k_b)
    col_nnz_a = a_ok.sum(axis=0)           # nnzcol_A(c)
    row_nnz_b = b_ok.sum(axis=1)           # nnzrow_B(c)
    # valid_products can exceed int32 on paper-scale matrices (it is a model
    # input, not a materialized stream) — reduce on the host in int64, as
    # stats_from_scipy does; jnp int64 is unavailable with x64 disabled.
    valid = np.asarray(jax.device_get(col_nnz_a), np.int64) @ \
        np.asarray(jax.device_get(row_nnz_b), np.int64)
    rows = jnp.where(a.idx >= 0, a.idx, a.n_rows).reshape(-1)
    row_nnz_a = jax.ops.segment_sum(a_ok.astype(jnp.int32).reshape(-1), rows,
                                    num_segments=a.n_rows + 1)[: a.n_rows]
    if nnz_c is None:
        # Row-flop upper bound on nnz(C), clipped to the row width (the
        # planner passes the exact count from plan/symbolic instead).
        # Reduced fully on the host: per-row flop counts can exceed int32 at
        # the modeling-only scales this function serves (same reason as
        # `valid`), and jnp int64 is unavailable with x64 disabled.
        w = np.asarray(jax.device_get(row_nnz_b), np.float64)   # (n,)
        idx = np.asarray(jax.device_get(a.idx))                 # (k_a, n)
        ok = idx >= 0
        wmat = np.broadcast_to(w[None, :], idx.shape)
        flops_per_row = np.bincount(idx[ok].ravel(),
                                    weights=wmat[ok].ravel(),
                                    minlength=a.n_rows)
        nnz_c = int(np.minimum(flops_per_row, b.n_cols).sum())
    return MatrixStats(
        n=max(a.n_rows, b.n_cols), nnz_a=int(a_ok.sum()), nnz_b=int(b_ok.sum()),
        k_a=a.k, k_b=b.k, valid_products=int(valid), nnz_c=int(nnz_c),
        sigma=float(jnp.std(row_nnz_a.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# SPLIM (ours) — structured multiply + in-situ search accumulate
# ---------------------------------------------------------------------------

def splim_latency(s: MatrixStats, cfg: SplimConfig = SplimConfig()) -> Dict[str, float]:
    """§III latency structure:

    mult   — ceil(k_a·k_b / P) sequential slab-pair iterations per PE (the
             ring delivers a new pairing each rotation); within an iteration
             the n-lane vector is array-parallel (n/1024 arrays, capped by
             the PE's array budget).
    ring   — 2 RowClones per rotation, T rotations, OCI-bandwidth bound.
    search — O(n·k) bit-serial CI iterations (Alg. 1), PE-parallel over
             disjoint intermediate sets; each iteration scans 32 bits and
             emits one coordinate group.
    acc    — one fp32 add per duplicate product on the per-PE accumulator,
             pipelined *behind* the search (overlapped ⇒ max, not sum).
    """
    pair_iters = math.ceil(s.k_a * s.k_b / cfg.n_pes)
    array_rounds = math.ceil(
        (s.n / cfg.array_rows) / cfg.arrays_per_pe)
    t_mult = pair_iters * max(1, array_rounds) * cfg.mult_cycles / cfg.freq_hz

    seg_hops = 2 * cfg.rowclone_cycles / cfg.freq_hz
    ring_bytes = s.k_b * s.n * 4
    t_ring = cfg.n_pes * seg_hops + ring_bytes / cfg.oci_bw

    iters = s.n * max(s.k_a, s.k_b)
    per_iter = 32 * cfg.search_cycles_per_bit + 32      # scan + emit
    t_search = iters * per_iter / (cfg.freq_hz * cfg.n_pes)

    # column-parallel readout: one 1024-bit line = 32 fp32 per cycle feeds
    # the PE accumulator ("column-parallel read/write", Table II discussion)
    acc_lanes = cfg.array_cols // cfg.cells_per_f32
    t_acc = s.valid_products / (cfg.freq_hz * cfg.n_pes * acc_lanes)
    t_merge = max(t_search, t_acc)

    total = t_mult + t_ring + t_merge
    return {"mult": t_mult, "ring": t_ring, "search": t_search,
            "add": t_acc, "merge": t_merge, "total": total}


def splim_energy(s: MatrixStats, cfg: SplimConfig = SplimConfig()) -> Dict[str, float]:
    lat = splim_latency(s, cfg)
    # Activity-scaled: arrays burn power during mult/search; utilization-
    # weighted (only valid lanes switch; invalid lanes contribute leakage).
    util = min(1.0, s.valid_products / max(1, s.k_a * s.k_b * s.n))
    active = lat["mult"] + lat["merge"]
    e_array = cfg.array_power_w * cfg.n_pes * active * util
    e_leak = cfg.array_power_w * cfg.n_pes * active * (1 - util) * 0.15
    e_buf = cfg.buffer_power_w * cfg.n_pes * lat["total"]
    e_ctrl = cfg.ctrl_power_w * lat["total"]
    e_io = cfg.io_energy_per_byte * (s.nnz_c * 12 + (s.nnz_a + s.nnz_b) * 8)
    total = e_array + e_leak + e_buf + e_ctrl + e_io
    return {"array": e_array, "leakage": e_leak, "io": e_io, "ctrl": e_ctrl + e_buf,
            "total": total}


# ---------------------------------------------------------------------------
# COO-SPLIM — identical hardware, decompression computation paradigm (§IV-C)
# ---------------------------------------------------------------------------

def coo_splim_latency(s: MatrixStats, cfg: SplimConfig = SplimConfig()) -> Dict[str, float]:
    # Decompressed SpMV (Fig. 5): N SpMV iterations, each multiplying a dense
    # column of A against the decompressed rows of B → N·N lanes per
    # iteration, N iterations: O(N³) scalar lanes, utilization nnz-driven.
    lanes_per_iter = s.n * s.n
    rounds_per_iter = math.ceil(lanes_per_iter / cfg.lanes_total)
    t_mult = s.n * rounds_per_iter * cfg.mult_cycles / cfg.freq_hz
    # decompression traffic: scatter nnz into dense N² planes per operand
    t_remap = (s.n * s.n * 4 * 2) / cfg.oci_bw
    adds = s.n * s.n
    t_add = adds * cfg.add_cycles / (cfg.freq_hz * cfg.n_pes * cfg.arrays_per_pe)
    total = t_mult + t_remap + t_add
    return {"mult": t_mult, "remap": t_remap, "add": t_add, "total": total}


def coo_splim_energy(s: MatrixStats, cfg: SplimConfig = SplimConfig()) -> Dict[str, float]:
    lat = coo_splim_latency(s, cfg)
    util = min(1.0, s.nnz_a / (s.n * s.n))
    act = lat["mult"]
    e_array = cfg.array_power_w * cfg.n_pes * act * max(util, 1e-4)
    e_leak = cfg.array_power_w * cfg.n_pes * act * (1 - util) * 0.35
    e_buf = cfg.buffer_power_w * cfg.n_pes * lat["total"]
    e_ctrl = cfg.ctrl_power_w * lat["total"]
    e_io = cfg.io_energy_per_byte * (s.n * s.n * 8)
    total = e_array + e_leak + e_buf + e_ctrl + e_io
    return {"array": e_array, "leakage": e_leak, "io": e_io, "ctrl": e_ctrl + e_buf,
            "total": total}


# ---------------------------------------------------------------------------
# Comparison-platform proxies (GPU / SAM / SpaceA / ReFlip), anchored to the
# paper's reported fleet means (§VI-A). Per-matrix shape comes from the
# model; the single scalar CAL_* anchors the mean.
# ---------------------------------------------------------------------------

A6000_FP32 = 38.7e12        # peak fp32 FLOP/s
A6000_BW = 768e9            # GB/s HBM
A6000_TDP = 300.0           # W
SPGEMM_GPU_EFF = 0.004      # cuSPARSE SpGEMM efficiency on scattered nnz
GPU_RANDOM_ACCESS_PENALTY = 24.0  # bytes amplification for unstructured gather


def gpu_latency(s: MatrixStats) -> float:
    t_compute = s.flops / (A6000_FP32 * SPGEMM_GPU_EFF)
    bytes_touched = (s.nnz_a + s.nnz_b + s.valid_products + s.nnz_c) * 8.0
    t_mem = bytes_touched * GPU_RANDOM_ACCESS_PENALTY / A6000_BW
    # irregularity penalty grows with row-imbalance (σ)
    imbalance = 1.0 + s.sigma / max(1.0, s.nnz_a / s.n)
    return (t_compute + t_mem) * imbalance


def gpu_energy(s: MatrixStats) -> float:
    return gpu_latency(s) * A6000_TDP * 0.55


def sam_latency(s: MatrixStats) -> float:
    # ASIC with off-chip DRAM streaming + on-chip scheduler (paper: 11.08x
    # slower than SPLIM on average); scheduler term scales with products.
    t_stream = (s.nnz_a + s.nnz_b + s.nnz_c) * 8.0 / 100e9
    t_sched = s.valid_products / 2e9
    return t_stream + t_sched


def spacea_latency(s: MatrixStats) -> float:
    # PIM near-bank PEs: limited parallelism + cross-bank traffic.
    t_pe = s.flops / 0.5e12
    t_xbank = s.valid_products * 8.0 / 50e9
    return t_pe + t_xbank


def spacea_energy(s: MatrixStats) -> float:
    return spacea_latency(s) * 60.0


def reflip_latency(s: MatrixStats) -> float:
    # PUM (analog, 3 iso-area chips) with decompression-based SpGEMM:
    # N SpMV iterations over decompressed N² planes; analog multi-level cells
    # are ~5x faster per op than digital bit-serial but lanes are wasted on
    # zeros (utilization ~ density).
    cfg = SplimConfig()
    rounds_per_iter = math.ceil((s.n * s.n) / (3 * cfg.lanes_total))
    t_mult = s.n * rounds_per_iter * (cfg.mult_cycles / 5.0) / cfg.freq_hz
    t_remap = (s.n * s.n * 8) / cfg.oci_bw      # decompression traffic
    return t_mult + t_remap


def reflip_energy(s: MatrixStats) -> float:
    return reflip_latency(s) * 150.0


PAPER_MEANS = {  # reported fleet-mean ratios vs SPLIM (paper §VI-A)
    "gpu_perf": 275.74, "gpu_energy": 687.19,
    "sam_perf": 11.08,
    "spacea_perf": 19.73, "spacea_energy": 13.4,
    "reflip_perf": 3.94, "reflip_energy": 2.81,
}


def calibrate(stats_list) -> Dict[str, float]:
    """Single scalar per platform so the 16-matrix mean ratio matches the
    paper's reported mean (declared calibration, see module docstring)."""
    t_splim = np.array([splim_latency(s)["total"] for s in stats_list])
    e_splim = np.array([splim_energy(s)["total"] for s in stats_list])
    cal = {}
    for name, fn, target, base in [
        ("gpu_perf", gpu_latency, PAPER_MEANS["gpu_perf"], t_splim),
        ("sam_perf", sam_latency, PAPER_MEANS["sam_perf"], t_splim),
        ("spacea_perf", spacea_latency, PAPER_MEANS["spacea_perf"], t_splim),
        ("reflip_perf", reflip_latency, PAPER_MEANS["reflip_perf"], t_splim),
    ]:
        raw = np.array([fn(s) for s in stats_list])
        cal[name] = target / float(np.mean(raw / base))
    for name, fn, target in [
        ("gpu_energy", gpu_energy, PAPER_MEANS["gpu_energy"]),
        ("spacea_energy", spacea_energy, PAPER_MEANS["spacea_energy"]),
        ("reflip_energy", reflip_energy, PAPER_MEANS["reflip_energy"]),
    ]:
        raw = np.array([fn(s) for s in stats_list])
        cal[name] = target / float(np.mean(raw / e_splim))
    return cal
