"""Streaming fused SpGEMM accumulation — slab-scan multiply→compact→merge.

The paper's BSS memory argument (§III-A, Fig. 8) is that slab products are
*streamed* into accumulation: the hardware never holds the full product
stream, only the tile of the current iteration. The ``'sort'``/``'tiled'``/
``'bucket'``/``'hash'`` backends all break that — they accumulate a fully
materialized ``(k_a, n, k_b)`` product tensor (12 B/lane, mostly INVALID
ELLPACK-padding lanes) and sort *all* of it. This module is the faithful
streaming realization: the working set is bounded by one slab-group tile
plus the running output buffer, O(group·n·k_b + out_cap), independent of
``k_a``.

Per ``lax.scan`` step over A slab groups:

  1. **multiply + sort** — the group's (group, n, k_b) product tile is
     formed, packed into int32 coordinate keys and sorted. On TPU with
     ``group=1`` this is one fused Pallas kernel
     (kernels/fused_sccp_stream) so unsorted products never touch HBM;
     off-TPU the identical contract goes through XLA's fused ``lax.sort``
     (kernels/ops.fused_slab_sort picks), and the planner sizes ``group``
     so the tile amortizes the per-step dispatch floor while staying ≪ the
     full stream.
  2. **compact** — run tails (the tile's unique coordinates with their
     totals) are packed to the front of a ``stream_cap``-lane buffer. The
     INVALID padding lanes — the dead weight that dominates the
     materialized backends — die here, inside the step. Compaction is
     cumsum + ``searchsorted`` + a single cap-sized take: no scatters (slow
     element loops on CPU XLA) and no gathers inside unrolled networks (the
     pinned-jax compile hazard — one take per scan body traces once).
  3. **merge** — the compacted tile is merged into the running sorted,
     coalesced buffer and the result compacted back to the buffer width.
     On TPU the merge is the bitonic two-list network
     (kernels.bitonic_merge.merge_coalesce_pair — reshape/flip partner
     exchange, no gathers); off-TPU one fused ``lax.sort`` over the
     concatenated pair realizes the same contract without putting ~100
     dispatch-bound vector ops in the innermost loop. Both lists are
     duplicate-free, so merged runs have length ≤ 2 and the run total is a
     single shifted add.

``StreamState.dropped`` counts every unique coordinate lost to an
undersized ``stream_cap`` or buffer; any drop poisons ``Coo.ngroups`` past
the cap (the repo-wide overflow contract), so ``check_no_overflow`` raises
instead of returning silently-wrong output. Planner-sized runs
(plan.make_plan: ``stream_cap``/``stream_group`` from the exact per-slab
product histogram, ``out_cap`` from the symbolic phase) never drop.

Packed int32 keys require ``n_rows·n_cols < 2³¹``; ``spgemm_coo`` reroutes
larger coordinate spaces to the unpacked two-key ``'sort'`` path before
reaching this module.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.bitonic_merge import (KEY_INVALID, _segmented_total_rows,
                                         merge_coalesce_pair,
                                         next_pot as _pot)
from repro.kernels.sccp_multiply import auto_interpret
from repro.obs import trace as _obs

from .formats import Coo, EllCols, EllRows, INVALID


def _on_tpu() -> bool:
    # shared backend detection: the compiled-Pallas predicate, inverted
    return not auto_interpret()


class StreamState(NamedTuple):
    """Running sorted+coalesced output buffer of the streaming engine.

    ``key``/``tot``: (buf_cap,) ascending unique packed coordinates with
    their running totals, KEY_INVALID/0 padding after the first ``count``
    lanes. ``dropped`` counts unique coordinates lost to undersized caps —
    any non-zero poisons the final ``ngroups``.
    """

    key: jax.Array      # (buf_cap,) int32
    tot: jax.Array      # (buf_cap,) values
    count: jax.Array    # () int32 — valid unique lanes in the buffer
    dropped: jax.Array  # () int32 — uniques lost to stream_cap/buffer limits


def stream_init(buf_cap: int, dtype=jnp.float32, lead=()) -> StreamState:
    """Empty state. ``buf_cap`` must be a power of two (merge network width);
    ``lead`` adds leading batch axes (distributed/batched callers)."""
    assert buf_cap & (buf_cap - 1) == 0, f"buf_cap {buf_cap} must be pow2"
    return StreamState(
        key=jnp.full(lead + (buf_cap,), KEY_INVALID, jnp.int32),
        tot=jnp.zeros(lead + (buf_cap,), dtype),
        count=jnp.zeros(lead, jnp.int32),
        dropped=jnp.zeros(lead, jnp.int32))


def _coalesce_compact(key: jax.Array, tot: jax.Array, cap: int):
    """Pack a sorted run-tail-total stream's unique coordinates into ``cap``
    lanes (ascending, KEY_INVALID padding). Tails are already in ascending
    key order, so ``searchsorted`` over the tail prefix-sum maps output
    slot → source lane directly (two takes, no scatter). Tails beyond
    ``cap`` are counted, never silently lost.
    Returns ``(key, tot, count, dropped)``."""
    nxt = jnp.concatenate(
        [key[1:], jnp.full((1,), KEY_INVALID - 1, key.dtype)])
    tail = jnp.logical_and(key != nxt, key != KEY_INVALID)
    csum = jnp.cumsum(tail.astype(jnp.int32))
    n_tail = csum[-1]
    src = jnp.searchsorted(csum, jnp.arange(1, cap + 1, dtype=jnp.int32))
    ok = jnp.arange(cap) < jnp.minimum(n_tail, cap)
    src = jnp.minimum(src, key.shape[0] - 1)
    out_key = jnp.where(ok, key[src], KEY_INVALID)
    out_tot = jnp.where(ok, tot[src], 0)
    return (out_key, out_tot, jnp.minimum(n_tail, cap),
            jnp.maximum(n_tail - cap, 0))


def _merge_coalesced(key_a, tot_a, key_b, tot_b):
    """Merge two same-length ascending *duplicate-free* lists into one
    sorted run-tail-total stream. TPU: the bitonic two-list network
    (no gathers); elsewhere one fused ``lax.sort`` — each key appears at
    most twice, so the run total is one shifted add."""
    if _on_tpu():
        return merge_coalesce_pair(key_a, tot_a, key_b, tot_b)
    key = jnp.concatenate([key_a, key_b])
    tot = jnp.concatenate([tot_a, tot_b])
    key, tot = jax.lax.sort((key, tot), dimension=0, num_keys=1,
                            is_stable=False)
    prev_k = jnp.concatenate(
        [jnp.full((1,), -2, key.dtype), key[:-1]])    # -2: never a key
    prev_t = jnp.concatenate([jnp.zeros((1,), tot.dtype), tot[:-1]])
    tot = tot + jnp.where(prev_k == key, prev_t, 0)   # run length ≤ 2
    return key, tot


def absorb_sorted(state: StreamState, key: jax.Array, tot: jax.Array, *,
                  stream_cap: int) -> StreamState:
    """Compact one sorted run-tail-total tile and merge it into the buffer.

    The compaction width is ``min(stream_cap, buf_cap)`` — a tile can never
    contribute more surviving uniques than the buffer holds, so a
    planner-sized ``stream_cap`` larger than the buffer costs nothing.
    """
    buf_cap = state.key.shape[-1]
    cap = min(int(stream_cap), buf_cap)
    with _obs.span("stream.compact", cap=cap):
        k_t, v_t, _, drop_t = _obs.sync(_coalesce_compact(key, tot, cap))
    if cap < buf_cap:                      # pad keeps the list ascending
        k_t = jnp.concatenate(
            [k_t, jnp.full((buf_cap - cap,), KEY_INVALID, k_t.dtype)])
        v_t = jnp.concatenate([v_t, jnp.zeros((buf_cap - cap,), v_t.dtype)])
    with _obs.span("stream.merge", buf_cap=buf_cap):
        mk, mt = _merge_coalesced(state.key, state.tot, k_t, v_t)
        k_b, v_b, count, drop_m = _obs.sync(
            _coalesce_compact(mk, mt, buf_cap))
    return StreamState(key=k_b, tot=v_b, count=count,
                       dropped=state.dropped + drop_t + drop_m)


def _sort_tile(row: jax.Array, col: jax.Array, val: jax.Array,
               n_cols: int):
    """Pack one raw product tile and sort it (XLA fused sort + log-step
    segmented totals — the same contract ops.fused_slab_sort emits)."""
    row, col, val = row.reshape(-1), col.reshape(-1), val.reshape(-1)
    pot = _pot(row.shape[0])
    key = jnp.where(row >= 0, row * n_cols + col,
                    KEY_INVALID).astype(jnp.int32)
    pad = pot - key.shape[0]
    if pad:
        key = jnp.concatenate(
            [key, jnp.full((pad,), KEY_INVALID, key.dtype)])
        val = jnp.concatenate([val, jnp.zeros((pad,), val.dtype)])
    key, val = jax.lax.sort((key, val), dimension=0, num_keys=1,
                            is_stable=False)
    tot = _segmented_total_rows(key[None, :], val[None, :])[0]
    return key, tot


def absorb_products(state: StreamState, row: jax.Array, col: jax.Array,
                    val: jax.Array, *, n_cols: int,
                    stream_cap: int) -> StreamState:
    """Stream a block of raw product tiles through sort→compact→merge.

    ``row``/``col``/``val``: (tiles, m) — one step per leading-axis tile
    via ``lax.scan`` (the 2-D reshape is the caller's slab grouping; a 1-D
    stream is treated as a single tile). Working set per step: one tile +
    the buffer, never the whole block.
    """
    if row.ndim == 1:
        row, col, val = row[None], col[None], val[None]

    def step(st, rcv):
        r, c, v = rcv
        key, tot = _sort_tile(r, c, v, n_cols)
        return absorb_sorted(st, key, tot, stream_cap=stream_cap), ()

    state, _ = jax.lax.scan(step, state, (row, col, val))
    return state


def finalize(state: StreamState, out_cap: int, n_rows: int,
             n_cols: int) -> Coo:
    """Unpack the buffer into ``Coo(out_cap)``. ``ngroups`` is the true
    unique count while nothing was dropped; any drop (or uniques beyond
    ``out_cap`` surviving in an oversized buffer) pushes it past the cap so
    the overflow machinery flags the loss."""
    buf_cap = state.key.shape[-1]
    key, tot = state.key, state.tot
    if buf_cap < out_cap:
        key = jnp.concatenate(
            [key, jnp.full((out_cap - buf_cap,), KEY_INVALID, key.dtype)])
        tot = jnp.concatenate(
            [tot, jnp.zeros((out_cap - buf_cap,), tot.dtype)])
    key, tot = key[:out_cap], tot[:out_cap]
    valid = key != KEY_INVALID
    row = jnp.where(valid, key // n_cols, INVALID).astype(jnp.int32)
    col = jnp.where(valid, key % n_cols, INVALID).astype(jnp.int32)
    val = jnp.where(valid, tot, 0)
    ngroups = state.count + jnp.where(state.dropped > 0,
                                      jnp.int32(out_cap + 1), jnp.int32(0))
    return Coo(row=row, col=col, val=val, shape=(n_rows, n_cols),
               ngroups=ngroups.astype(jnp.int32))


def _check_packable(n_rows: int, n_cols: int):
    if n_rows * n_cols >= jnp.iinfo(jnp.int32).max:
        raise ValueError(
            f"coordinate space {n_rows}x{n_cols} exceeds packed int32 keys; "
            "the streaming engine cannot span it — use the unpacked two-key "
            "path (spgemm_coo(accumulator='sort') routes automatically)")


def buffer_cap(out_cap: int, *, lane: int = 128) -> int:
    """Merge-buffer width for a given output capacity: power of two, at
    least one VPU lane tile."""
    return _pot(max(int(out_cap), lane))


def spgemm_coo_stream(a: EllRows, b: EllCols, out_cap="auto", *,
                      stream_cap: Optional[int] = None,
                      group: Optional[int] = None) -> Coo:
    """C = A·B as sorted COO without ever materializing the product stream.

    Prefer ``repro.spgemm(a, b, accumulator='stream')`` — the unified front
    door (core/api.py) routes here with the same semantics.

    ``lax.scan`` over groups of ``group`` A slabs: per step one
    (group, n, k_b) tile is multiplied, sorted (fused in VMEM on TPU when
    ``group=1`` — ops.fused_slab_sort), compacted to its unique coordinates
    and merged into the running buffer. Peak intermediate is
    O(group·n·k_b + stream_cap) vs the materialized backends'
    O(k_a·n·k_b). ``stream_cap`` defaults to the full group tile (never
    drops); the planner passes the exact per-slab product bound and sizes
    ``group`` to amortize the off-TPU per-step dispatch floor.
    jit/vmap-compatible with static caps; ``out_cap='auto'`` (and
    ``group=None``) run ``plan.make_plan(backend='stream')`` on concrete
    operands, matching every other entry point's auto-sizing.
    """
    if a.n_cols != b.n_rows:
        raise ValueError(f"contraction mismatch: A has {a.n_cols} cols, "
                         f"B has {b.n_rows} rows")
    _check_packable(a.n_rows, b.n_cols)
    if out_cap == "auto":
        if isinstance(a.val, jax.core.Tracer):
            raise ValueError(
                "out_cap='auto' plans from operand VALUES, which jit/vmap "
                "abstract away — call plan.make_plan(backend='stream') "
                "outside the trace and pass its out_cap, or a concrete int")
        from repro.plan import make_plan
        plan = make_plan(a, b, backend="stream")
        out_cap = plan.out_cap
        stream_cap = plan.stream_cap if stream_cap is None else stream_cap
        group = plan.stream_group if group is None else group
    group = max(1, min(int(group or 1), a.k))
    from repro.kernels.ops import pad_to
    a_val = pad_to(a.val, 0, group, 0)
    a_idx = pad_to(a.idx, 0, group, INVALID)
    n_groups = a_val.shape[0] // group
    tile_lanes = group * a.n_cols * b.k
    scap = int(stream_cap) if stream_cap else _pot(tile_lanes)
    state0 = stream_init(buffer_cap(out_cap), a.val.dtype)
    fused = _on_tpu() and group == 1

    def tile_sorted(g):
        av = jax.lax.dynamic_slice_in_dim(a_val, g * group, group, 0)
        ai = jax.lax.dynamic_slice_in_dim(a_idx, g * group, group, 0)
        if fused:
            from repro.kernels import ops
            return ops.fused_slab_sort(av[0], ai[0], b.val, b.idx,
                                       n_cols=b.n_cols)
        v = av[:, :, None] * b.val[None, :, :]            # (group, n, k_b)
        r = jnp.broadcast_to(ai[:, :, None], v.shape)
        ok = jnp.logical_and(r >= 0, b.idx[None, :, :] >= 0)
        return _sort_tile(
            jnp.where(ok, r, INVALID),
            jnp.where(ok, b.idx[None, :, :], INVALID),
            jnp.where(ok, v, 0), b.n_cols)

    def step(st, g):
        key, tot = tile_sorted(g)
        return absorb_sorted(st, key, tot, stream_cap=scap), ()

    if _obs.is_enabled() and not isinstance(a.val, jax.core.Tracer):
        # Traced mode: unroll the scan in Python — the identical tiles in
        # the identical order (float-identical result), but each slab step
        # gets its own multiply+sort / compact+merge spans with device
        # syncs. Only reachable outside jit with concrete operands.
        state = state0
        for g in range(n_groups):
            with _obs.span("stream.step", step=g, group=group, fused=fused):
                with _obs.span("stream.sort", lanes=tile_lanes):
                    key, tot = _obs.sync(tile_sorted(jnp.int32(g)))
                state = absorb_sorted(state, key, tot, stream_cap=scap)
    else:
        state, _ = jax.lax.scan(step, state0, jnp.arange(n_groups))
    return finalize(state, out_cap, a.n_rows, b.n_cols)


def spgemm_coo_stream_numeric(a: EllRows, b: EllCols, structure, *,
                              check: bool = False,
                              validate: bool = True) -> Coo:
    """Numeric phase of the streaming path: slab-scan scatter into a
    precomputed structure (plan.make_structure) — ``repro.spgemm(a, b,
    structure=st)`` reaches this realization automatically for
    stream-planned structures; call this wrapper only to force it. Same
    O(group·n·k_b + out_cap) working set as ``spgemm_coo_stream`` but with
    the per-step sort/compact/merge machinery replaced by one
    ``searchsorted`` + segment-sum per step — the structure already knows
    every output coordinate. Thin streaming-layer alias of the dispatch
    ``core.spgemm.spgemm_coo_numeric`` performs for stream-backed plans;
    use this to force the slab-scan realization regardless of the
    structure's planned backend."""
    if validate:
        structure.validate(a, b)
    from .spgemm import _numeric_stream
    plan = structure.plan
    grp = 1 if plan is None else max(1, min(plan.stream_group, a.k))
    coo = _numeric_stream(a.val, a.idx, b.val, b.idx, structure.key,
                          structure.nnz, out_cap=structure.out_cap,
                          n_rows=structure.n_rows, n_cols=structure.n_cols,
                          group=grp)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


def accumulate_products_stream(row: jax.Array, col: jax.Array,
                               val: jax.Array, out_cap: int, n_rows: int,
                               n_cols: int, *, chunk: int = 4096,
                               stream_cap: Optional[int] = None,
                               group: int = 1) -> Coo:
    """Streaming accumulation of an already-materialized product stream.

    The ``accumulate_stream(backend='stream')`` realization: the caller
    holds the products, but the *sort* working set stays one tile. A 3-D
    ``(k_a, n, k_b)`` stream is chunked by groups of ``group`` slabs —
    bit-identical (float-exact) to ``spgemm_coo_stream`` on the same
    operands and plan, which scans the identical tiles in the identical
    order. Flat streams are chunked by ``chunk`` lanes; ``stream_cap`` is a
    *slab-group* unique bound, meaningless for an arbitrary lane chunk, so
    the flat path compacts at the full chunk width (never drops).
    """
    _check_packable(n_rows, n_cols)
    from repro.kernels.ops import pad_to
    if row.ndim == 3:
        group = max(1, min(int(group), row.shape[0]))
        row = pad_to(row, 0, group, INVALID)
        col = pad_to(col, 0, group, INVALID)
        val = pad_to(val, 0, group, 0)
        tiles = row.shape[0] // group
        row, col, val = (x.reshape(tiles, -1) for x in (row, col, val))
    else:
        row, col, val = row.reshape(-1), col.reshape(-1), val.reshape(-1)
        chunk = min(chunk, _pot(row.shape[0]))
        row = pad_to(row, 0, chunk, INVALID)
        col = pad_to(col, 0, chunk, INVALID)
        val = pad_to(val, 0, chunk, 0)
        row, col, val = (x.reshape(-1, chunk) for x in (row, col, val))
        stream_cap = None
    scap = int(stream_cap) if stream_cap else _pot(row.shape[-1])
    state = stream_init(buffer_cap(out_cap), val.dtype)
    state = absorb_products(state, row, col, val, n_cols=n_cols,
                            stream_cap=scap)
    return finalize(state, out_cap, n_rows, n_cols)
