"""SCCP — Structured Condensing Computation Paradigm (paper §III-A, Fig. 7/8).

The multiply phase of SPLIM: every (A row-vector, B column-vector) slab pair
is combined **element-wise along the shared/contraction axis** — the column
coordinate of A and the row coordinate of B are aligned *by physical
position*, so the multiply is fully structured (no decompression, no zeros
beyond ELLPACK padding):

    P[i, c, j]   = A.val[i, c] * B.val[c, j]
    row(P[i,c,j]) = A.idx[i, c]          (unstructured — resolved later)
    col(P[i,c,j]) = B.idx[c, j]

This mirrors the memristor arrays computing V_a ⊙ V_b in one shot; the ring
rotation of B slabs across arrays (Fig. 6c) appears in distributed.py as a
``ppermute`` ring. On a single device all k_a × k_b pairs are expressed as one
broadcasted product, which XLA fuses into a single pass over VMEM-sized tiles
(kernels/sccp_multiply.py is the explicitly tiled Pallas version).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .formats import EllCols, EllRows, INVALID


def sccp_multiply(a: EllRows, b: EllCols) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """All slab-pair products.

    Returns ``(val, row, col)`` each of shape ``(k_a, n, k_b)`` where ``n`` is
    the shared dimension. Invalid lanes (either operand slot empty) carry
    row = col = -1 and val = 0.
    """
    if a.n_cols != b.n_rows:
        raise ValueError(f"contraction mismatch: A has {a.n_cols} cols, B has {b.n_rows} rows")
    av = a.val[:, :, None]                 # (k_a, n, 1)
    bv = b.val[None, :, :]                 # (1, n, k_b)
    val = av * bv                          # (k_a, n, k_b)
    row = jnp.broadcast_to(a.idx[:, :, None], val.shape)
    col = jnp.broadcast_to(b.idx[None, :, :], val.shape)
    ok = (row >= 0) & (col >= 0)
    val = jnp.where(ok, val, 0)
    row = jnp.where(ok, row, INVALID)
    col = jnp.where(ok, col, INVALID)
    return val, row, col


def sccp_multiply_slab(a: EllRows, b: EllCols, i: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Products of A slab ``i`` against *all* B slabs: shapes ``(n, k_b)``.

    Streaming building block — one "iteration" of the paper's Fig. 8, used by
    spgemm.py's scan so the intermediate working set stays O(n·k_b) instead of
    O(n·k_a·k_b) (the paper's BSS capacity argument, §III-A Memory analysis).
    """
    av = jax.lax.dynamic_index_in_dim(a.val, i, axis=0, keepdims=False)  # (n,)
    ai = jax.lax.dynamic_index_in_dim(a.idx, i, axis=0, keepdims=False)  # (n,)
    val = av[:, None] * b.val              # (n, k_b)
    row = jnp.broadcast_to(ai[:, None], val.shape)
    col = b.idx
    ok = (row >= 0) & (col >= 0)
    return (jnp.where(ok, val, 0),
            jnp.where(ok, row, INVALID),
            jnp.where(ok, col, INVALID))


def count_products_rows(a: EllRows, b: EllCols) -> jax.Array:
    """Per-output-row SCCP product counts (row-flop counting, no stream).

    Output row r receives Σ_{lanes of A with idx==r} nnzrow_B(c) products —
    one segment-sum over the (k_a, n) A plane weighted by B's per-row nnz.
    Clipped to the row width this upper-bounds the per-row nnz(C); the
    symbolic planner (plan/symbolic) and hwmodel's nnz_c fallback both
    build on it.

    int32 is exact here because per-row products are bounded by the total
    SCCP stream k_a·n·k_b, which must be *materializable* (12 bytes/lane)
    for any of the stream-based accumulators to run — far below 2³¹ lanes.
    For modeling-only product counts on matrices too large to execute, use
    ``hwmodel.stats_from_scipy`` / ``stats_from_ell`` (host-side int64).
    """
    b_row_nnz = b.valid_mask().sum(axis=1)                 # (n,) nnzrow_B(c)
    w = jnp.broadcast_to(b_row_nnz[None, :], a.idx.shape)  # (k_a, n)
    rows = jnp.where(a.idx >= 0, a.idx, a.n_rows).reshape(-1)
    per_row = jax.ops.segment_sum(
        jnp.where(a.idx >= 0, w, 0).reshape(-1), rows,
        num_segments=a.n_rows + 1)[: a.n_rows]
    return per_row.astype(jnp.int32)


def count_products(a: EllRows, b: EllCols) -> jax.Array:
    """Number of *valid* scalar multiplies SCCP performs (= paper's NK² term).

    Used by hwmodel.py for latency/energy and by the utilization benchmark
    (Fig. 16): valid lanes / total lanes is exactly the paper's "array
    utilization".
    """
    a_ok = a.valid_mask()                  # (k_a, n)
    b_ok = b.valid_mask()                  # (n, k_b)
    return jnp.sum(a_ok.sum(0) * b_ok.sum(1))
