"""SPLIM core: structured in-situ SpGEMM in JAX (paper's primary contribution).

Public API:
  api          — the unified ``spgemm()`` front door (prefer ``repro.spgemm``)
  formats      — COO / ELLPACK(row/col-wise) / hybrid containers + converters
  nm           — N:M balanced-sparsity condensed weight planes (NmWeights)
  sccp         — Structured Condensing Computation Paradigm multiply
  accumulate   — in-situ-search-equivalent sorted merge
  spgemm       — end-to-end spgemm / spmm entry points
  hybrid       — NNZ-a + σ hybrid ELLPACK+COO splitting
  hwmodel      — analytical PUM latency/energy model (paper Table II)
  distributed  — sparse-native ring-schedule SpGEMM on the mesh (paper
                 Fig. 6c): ``spgemm_coo_sharded`` with device-local planned
                 accumulation and an owner-binned COO exchange

The accumulation-backend planner (symbolic nnz(C) sizing, sort/tiled/
bucket/hash selection) lives one layer up in ``repro.plan``; ``spgemm_coo``
reaches it via ``out_cap='auto'`` / ``accumulator='auto'``.

Note: the ``spgemm`` *function* is deliberately not re-exported here — the
submodule of the same name owns this namespace slot; reach the front door
as ``repro.spgemm`` or ``repro.core.api.spgemm``.
"""
from . import (accumulate, api, distributed, formats, hwmodel, hybrid, nm,
               sccp, spgemm, streaming)
from .streaming import spgemm_coo_stream
from .accumulate import AccumulatorOverflow, accumulate_checked, check_no_overflow
from .distributed import (ring_spgemm, spgemm_coo_sharded,
                          spgemm_coo_sharded_batched)
from .formats import (Coo, EllCols, EllRows, coo_from_dense,
                      ell_cols_from_dense, ell_rows_from_dense)
from .nm import NmWeights, detect_nm, nm_from_dense
from .spgemm import (accumulate_stream, spgemm_coo, spgemm_coo_batched,
                     spgemm_dense, spgemm_dense_batched, spgemm_from_dense,
                     spgemm_streaming, spmm_ell_dense)

__all__ = [
    "accumulate", "api", "distributed", "formats", "hwmodel", "hybrid",
    "nm", "sccp", "spgemm", "streaming",
    "AccumulatorOverflow", "accumulate_checked", "check_no_overflow",
    "Coo", "EllCols", "EllRows", "NmWeights", "coo_from_dense",
    "detect_nm", "ell_cols_from_dense", "ell_rows_from_dense",
    "nm_from_dense", "accumulate_stream", "ring_spgemm",
    "spgemm_coo", "spgemm_coo_batched", "spgemm_coo_sharded",
    "spgemm_coo_sharded_batched", "spgemm_coo_stream", "spgemm_dense",
    "spgemm_dense_batched", "spgemm_from_dense", "spgemm_streaming",
    "spmm_ell_dense",
]
