"""Sparse-matrix storage formats used by SPLIM (paper §II-A, Fig. 2).

All containers are registered pytrees with *static* shapes so every op is
jittable. Empty ELLPACK slots carry index ``-1`` (the paper's "invalid" marker,
realised in hardware by flipping the sign bit, §III-B); empty COO slots carry
row = col = -1.

Orientation convention (paper Fig. 6/7):
  * ``EllRows``  — *row-wise* ELLPACK of the **left** matrix A: non-zeros of
    every column are condensed upward into ``k`` dense "row vectors".
    ``val[s, c]`` is the s-th non-zero of column ``c`` of A and ``idx[s, c]``
    is its original **row** coordinate (the column coordinate is the physical
    position ``c``).
  * ``EllCols``  — *column-wise* ELLPACK of the **right** matrix B: non-zeros
    of every row condensed leftward into ``k`` "column vectors".
    ``val[r, s]`` is the s-th non-zero of row ``r`` of B, ``idx[r, s]`` its
    original **column** coordinate.

With this pair the SCCP slab product (sccp.py) aligns the contraction
dimension *by physical position* — no decompression, exactly the paper's
insight.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INVALID = -1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EllRows:
    """Row-wise ELLPACK (left operand). val/idx: (k, n)."""

    val: jax.Array  # (k, n) float
    idx: jax.Array  # (k, n) int32, original row coord, -1 = empty
    n_rows: int     # logical number of rows of the original matrix

    def tree_flatten(self):
        return (self.val, self.idx), (self.n_rows,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], aux[0])

    @property
    def k(self) -> int:
        return self.val.shape[0]

    @property
    def n_cols(self) -> int:
        return self.val.shape[1]

    def valid_mask(self) -> jax.Array:
        return self.idx >= 0

    def to_dense(self) -> jax.Array:
        """Scatter back to (n_rows, n_cols). Oracle/debug only."""
        k, n = self.val.shape
        rows = jnp.where(self.idx >= 0, self.idx, self.n_rows)  # park invalid
        cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (k, n))
        dense = jnp.zeros((self.n_rows + 1, n), self.val.dtype)
        dense = dense.at[rows.reshape(-1), cols.reshape(-1)].add(
            jnp.where(self.idx >= 0, self.val, 0).reshape(-1))
        return dense[: self.n_rows]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class EllCols:
    """Column-wise ELLPACK (right operand). val/idx: (n, k)."""

    val: jax.Array  # (n, k) float
    idx: jax.Array  # (n, k) int32, original column coord, -1 = empty
    n_cols: int

    def tree_flatten(self):
        return (self.val, self.idx), (self.n_cols,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], aux[0])

    @property
    def k(self) -> int:
        return self.val.shape[1]

    @property
    def n_rows(self) -> int:
        return self.val.shape[0]

    def valid_mask(self) -> jax.Array:
        return self.idx >= 0

    def to_dense(self) -> jax.Array:
        n, k = self.val.shape
        cols = jnp.where(self.idx >= 0, self.idx, self.n_cols)
        rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[:, None], (n, k))
        dense = jnp.zeros((n, self.n_cols + 1), self.val.dtype)
        dense = dense.at[rows.reshape(-1), cols.reshape(-1)].add(
            jnp.where(self.idx >= 0, self.val, 0).reshape(-1))
        return dense[:, : self.n_cols]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Coo:
    """Padded COO. Invalid (padding) entries have row = col = -1.

    ``ngroups`` (optional leaf) is the TRUE number of unique coordinates the
    producing op saw — it may exceed ``cap``, in which case the stored stream
    was truncated and ``overflowed()`` flags the loss (see
    accumulate.check_no_overflow). ``None`` means the producer didn't count.
    """

    row: jax.Array  # (cap,) int32
    col: jax.Array  # (cap,) int32
    val: jax.Array  # (cap,) float
    shape: Tuple[int, int]
    ngroups: Optional[jax.Array] = None  # () int32, true unique-coord count

    def tree_flatten(self):
        return (self.row, self.col, self.val, self.ngroups), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], leaves[1], leaves[2], aux[0], leaves[3])

    @property
    def cap(self) -> int:
        return self.row.shape[0]

    def valid_mask(self) -> jax.Array:
        return self.row >= 0

    def nnz(self) -> jax.Array:
        return jnp.sum(self.valid_mask())

    def overflowed(self) -> jax.Array:
        """Traced bool: did the producer drop groups beyond ``cap``?
        Batched ``Coo`` (leading batch axis) yields a per-batch bool."""
        if self.ngroups is None:
            return jnp.zeros((), bool)
        return self.ngroups > self.row.shape[-1]

    def to_dense(self) -> jax.Array:
        m, n = self.shape
        r = jnp.where(self.row >= 0, self.row, m)
        c = jnp.where(self.col >= 0, self.col, 0)
        dense = jnp.zeros((m + 1, n), self.val.dtype)
        dense = dense.at[r, c].add(jnp.where(self.row >= 0, self.val, 0))
        return dense[:m]


# ---------------------------------------------------------------------------
# Dense -> format converters (jittable; k / cap are static)
# ---------------------------------------------------------------------------

def _condense(mask: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Stable-sort a boolean mask along axis 0 so True entries pack first.

    Returns (perm, keep): ``perm[s, c]`` = source row of slot s in column c,
    ``keep`` marks slots that actually hold a non-zero.
    """
    n = mask.shape[0]
    # argsort of (not mask) is stable -> non-zeros first, original order kept.
    perm = jnp.argsort(jnp.logical_not(mask), axis=0, stable=True)
    counts = jnp.sum(mask, axis=0)  # per column
    slot = jnp.arange(k, dtype=jnp.int32)[:, None]
    keep = slot < counts[None, :]
    return perm[:k], keep


def ell_rows_from_dense(a: jax.Array, k: int) -> EllRows:
    """Row-wise ELLPACK (condense each *column* upward) of left matrix A.

    Entries beyond slot ``k`` in a column are dropped — callers that need
    losslessness must pick ``k >= max col nnz`` or use hybrid.py.
    """
    m, n = a.shape
    mask = a != 0
    perm, keep = _condense(mask, k)
    cols = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (k, n))
    val = jnp.where(keep, a[perm, cols], 0).astype(a.dtype)
    idx = jnp.where(keep, perm.astype(jnp.int32), INVALID)
    return EllRows(val=val, idx=idx, n_rows=m)


def ell_cols_from_dense(b: jax.Array, k: int) -> EllCols:
    """Column-wise ELLPACK (condense each *row* leftward) of right matrix B."""
    m, n = b.shape
    mask = (b != 0).T                      # (n_cols, n_rows) -> condense cols of Bᵀ
    perm, keep = _condense(mask, k)        # (k, m)
    rows = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32), (k, m))
    val = jnp.where(keep, b.T[perm, rows], 0).astype(b.dtype)  # (k, m)
    idx = jnp.where(keep, perm.astype(jnp.int32), INVALID)
    return EllCols(val=val.T, idx=idx.T, n_cols=n)


def coo_from_dense(a: jax.Array, cap: int) -> Coo:
    """Dense -> padded COO (row-major order), jittable with static cap."""
    m, n = a.shape
    mask = (a != 0).reshape(-1)
    order = jnp.argsort(jnp.logical_not(mask), stable=True)[:cap]
    keep = jnp.arange(cap) < jnp.sum(mask)
    flat = a.reshape(-1)
    row = jnp.where(keep, (order // n).astype(jnp.int32), INVALID)
    col = jnp.where(keep, (order % n).astype(jnp.int32), INVALID)
    val = jnp.where(keep, flat[order], 0)
    return Coo(row=row, col=col, val=val, shape=(m, n),
               ngroups=jnp.sum(mask).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Host-side (numpy / scipy) constructors for benchmark-scale matrices
# ---------------------------------------------------------------------------

def np_ell_rows_from_scipy(a_csc, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """scipy CSC -> row-wise ELLPACK planes (numpy). Used by benchmarks."""
    a_csc = a_csc.tocsc()
    m, n = a_csc.shape
    val = np.zeros((k, n), dtype=np.float32)
    idx = np.full((k, n), INVALID, dtype=np.int32)
    indptr, indices, data = a_csc.indptr, a_csc.indices, a_csc.data
    for c in range(n):
        lo, hi = indptr[c], min(indptr[c + 1], indptr[c] + k)
        cnt = hi - lo
        val[:cnt, c] = data[lo:hi]
        idx[:cnt, c] = indices[lo:hi]
    return val, idx


def np_ell_cols_from_scipy(b_csr, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """scipy CSR -> column-wise ELLPACK planes (numpy)."""
    b_csr = b_csr.tocsr()
    m, n = b_csr.shape
    val = np.zeros((m, k), dtype=np.float32)
    idx = np.full((m, k), INVALID, dtype=np.int32)
    indptr, indices, data = b_csr.indptr, b_csr.indices, b_csr.data
    for r in range(m):
        lo, hi = indptr[r], min(indptr[r + 1], indptr[r] + k)
        cnt = hi - lo
        val[r, :cnt] = data[lo:hi]
        idx[r, :cnt] = indices[lo:hi]
    return val, idx
