"""End-to-end SPLIM SpGEMM: SCCP multiply → in-situ-search-style accumulate.

Public entry points:

  * ``spgemm_coo``      — C = A·B as sorted COO (the paper's output format).
                          Six accumulation backends: ``'sort'`` (global
                          ``jax.lax.sort``), ``'tiled'`` (multi-tile bitonic
                          merge tree, kernels.ops.sort_merge), ``'bucket'``
                          (propagation blocking, kernels.radix_bucket),
                          ``'hash'`` (per-row-block open addressing,
                          kernels.hash_accum), ``'stream'`` (slab-scan
                          multiply→compact→merge, core.streaming — the only
                          one that never materializes the (k_a, n, k_b)
                          product stream) and ``'search'`` (the paper's own
                          in-situ-search accumulation, kernels.insitu_search:
                          emit the sorted unique keys, align every product
                          against them — Alg. 1 / Fig. 11);
                          ``accumulator='auto'`` / ``out_cap='auto'`` route
                          through the planner (repro.plan), and
                          ``check=True`` raises on any truncation or backend
                          drop.
  * ``spgemm_dense``    — C dense (oracle / small-n convenience).
  * ``spgemm_streaming``— scan over A slabs so the intermediate working set is
                          O(n·k_b) (paper's Fig. 8 iteration + BSS memory
                          argument), scatter-accumulating into dense C.
  * ``spgemm_coo_batched`` / ``spgemm_dense_batched`` — vmap over a leading
                          batch axis of both ELLPACK operands (all shapes /
                          caps shared across the batch).
  * ``spmm_ell_dense``  — ELLPACK × dense matrix (powers MoE dispatch and
                          SparseLinear in the LM stack).

All are jittable with static k / caps, and the single-matrix entry points
are vmap-able (the batched wrappers are exactly that).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs

from .accumulate import accumulate, scatter_dense
from .formats import (INVALID, Coo, EllCols, EllRows, ell_cols_from_dense,
                      ell_rows_from_dense)
from .sccp import sccp_multiply, sccp_multiply_slab


def _plan_key(plan, n_rows: int, n_cols: int) -> str:
    """Metrics-ledger key for est-vs-measured joins: the plan fingerprint
    when available, else a shape tag."""
    fp = getattr(plan, "fp", None)
    return fp[:12] if fp else f"shape:{n_rows}x{n_cols}"


def _coo_from_merged(key: jax.Array, tot: jax.Array, out_cap: int,
                     n_rows: int, n_cols: int) -> Coo:
    """Compact a sort_merge stream (sorted keys, run-tail totals) to COO.

    O(n) scatter — tails are already in ascending key order, so a cumsum
    gives each one its output slot directly (no global sort: that would
    reintroduce the monolithic pass the merge tree exists to avoid).
    Non-tail lanes and overflow groups park in the discarded dump slot.
    """
    from repro.kernels.bitonic_merge import KEY_INVALID
    nxt = jnp.concatenate([key[1:], jnp.full((1,), KEY_INVALID - 1, key.dtype)])
    tail = jnp.logical_and(key != nxt, key != KEY_INVALID)
    ngroups = jnp.sum(tail)
    dst = jnp.where(tail, jnp.cumsum(tail) - 1, out_cap)
    dst = jnp.minimum(dst, out_cap)
    row = (jnp.full((out_cap + 1,), INVALID, jnp.int32)
           .at[dst].set((key // n_cols).astype(jnp.int32)))[:out_cap]
    col = (jnp.full((out_cap + 1,), INVALID, jnp.int32)
           .at[dst].set((key % n_cols).astype(jnp.int32)))[:out_cap]
    val = jnp.zeros((out_cap + 1,), tot.dtype).at[dst].set(tot)[:out_cap]
    return Coo(row=row, col=col, val=val, shape=(n_rows, n_cols),
               ngroups=ngroups.astype(jnp.int32))


def _poison_overflow(coo: Coo, dropped: jax.Array) -> Coo:
    """Fold a backend's dropped-product count into the overflow contract:
    any drop pushes ``ngroups`` past ``cap`` so ``overflowed()`` flags it and
    ``check_no_overflow`` raises — dropped products mean lost values, which
    must never pass for a clean result."""
    ng = coo.ngroups + jnp.where(dropped > 0,
                                 jnp.int32(coo.row.shape[-1] + 1),
                                 jnp.int32(0))
    return Coo(row=coo.row, col=coo.col, val=coo.val, shape=coo.shape,
               ngroups=ng)


def accumulate_stream(row: jax.Array, col: jax.Array, val: jax.Array,
                      out_cap: int, n_rows: int, n_cols: int, *,
                      backend: str = "sort", tile: int = 4096,
                      plan=None) -> Coo:
    """Run one accumulation backend over a raw product stream → sorted COO.

    The backend-dispatch half of ``spgemm_coo``, factored out so any
    producer of an (row, col, val) product stream — the single-device SCCP
    multiply, or a device-local slab stream inside the distributed ring —
    accumulates through the identical six backends. ``plan`` (repro.plan
    ``Plan``) supplies bucket/table blocking sizes; dropped products poison
    ``Coo.ngroups`` exactly as in ``spgemm_coo``.

    ``backend='stream'`` scans the stream tile-by-tile (3-D input: by its
    slab axis, bit-identical to the never-materialized ``spgemm_coo``
    stream path; flat input: by ``tile``-lane chunks) so the sort working
    set stays one tile — but the caller already paid for materializing the
    stream; ``spgemm_coo(accumulator='stream')`` avoids even that.

    Instrumented (repro.obs): a ``spgemm.accumulate`` span with a device
    sync, whose measured µs feeds the planner est-vs-measured ledger —
    disabled tracing takes the bare dispatch path untouched.
    """
    if not _obs.is_enabled():
        return _accumulate_impl(row, col, val, out_cap, n_rows, n_cols,
                                backend=backend, tile=tile, plan=plan)
    with _obs.span("spgemm.accumulate", backend=backend,
                   lanes=int(row.size), out_cap=int(out_cap)) as sp:
        coo = _accumulate_impl(row, col, val, out_cap, n_rows, n_cols,
                               backend=backend, tile=tile, plan=plan)
        _obs.sync(coo.val)
        if not isinstance(coo.ngroups, jax.core.Tracer):
            ng = int(coo.ngroups)
            sp.set(nnz=ng)
            if ng > out_cap and backend in ("bucket", "hash"):
                # backend drop → _poison_overflow stamped ngroups past cap
                _obs_metrics.inc("spgemm.poison_events")
                _obs.instant("spgemm.poison", backend=backend, ngroups=ng,
                             cap=int(out_cap))
    if sp.dur_us is not None and not isinstance(row, jax.core.Tracer):
        _obs_metrics.record_backend_us(_plan_key(plan, n_rows, n_cols),
                                       backend, sp.dur_us)
    return coo


def _accumulate_impl(row: jax.Array, col: jax.Array, val: jax.Array,
                     out_cap: int, n_rows: int, n_cols: int, *,
                     backend: str, tile: int, plan) -> Coo:
    if backend == "sort":
        return accumulate(row, col, val, out_cap, n_rows, n_cols)
    if backend == "stream":
        from .streaming import accumulate_products_stream
        scap = plan.stream_cap if plan is not None else None
        grp = plan.stream_group if plan is not None else 1
        return accumulate_products_stream(row, col, val, out_cap, n_rows,
                                          n_cols, chunk=tile,
                                          stream_cap=scap, group=grp)
    from repro.kernels import ops
    if backend == "tiled":
        key, tot = ops.sort_merge(row, col, val, n_rows, n_cols, tile=tile)
        return _coo_from_merged(key, tot, out_cap, n_rows, n_cols)
    if backend == "search":
        # Paper Alg. 1 / Fig. 11: emit the sorted unique keys, align every
        # product against them (kernels.insitu_search) — values are never
        # sorted. Truncation keeps the first out_cap unique keys and flags
        # via nnz > out_cap, exactly the 'sort' backend's contract; the
        # backend never internally drops, so no poisoning applies.
        uk, sums, nnz = ops.search_merge(row, col, val, n_rows, n_cols,
                                         out_cap=out_cap)
        return _coo_from_slots(uk, sums, nnz, out_cap=out_cap,
                               n_rows=n_rows, n_cols=n_cols)
    if backend == "bucket":
        kw = dict(n_buckets=plan.n_buckets, bucket_cap=plan.bucket_cap) \
            if plan is not None else {}
        key, tot, dropped = ops.bucket_merge(row, col, val, n_rows,
                                             n_cols, **kw)
        return _poison_overflow(
            _coo_from_merged(key, tot, out_cap, n_rows, n_cols), dropped)
    if backend == "hash":
        kw = dict(n_blocks=plan.n_blocks, block_cap=plan.block_cap,
                  max_probes=plan.max_probes) if plan is not None else {}
        key, tot, dropped = ops.hash_merge(row, col, val, n_rows,
                                           n_cols, **kw)
        return _poison_overflow(
            _coo_from_merged(key, tot, out_cap, n_rows, n_cols), dropped)
    raise ValueError(f"unknown accumulator {backend!r}")


def _validate_plan_fp(plan, a: EllRows, b: EllCols) -> None:
    """Raise on a stale caller-supplied plan: its sparsity fingerprint must
    match the operands'. Skipped for tracers (no bytes to hash — the host
    call that built the plan already validated) and for batched operands
    (reusing a representative-slice plan across a batch is the documented
    pattern). ``dataclasses.replace(plan, fp=None)`` opts out for deliberate
    reuse across similar patterns."""
    fp = getattr(plan, "fp", None)
    if fp is None or a.val.ndim != 2:
        return
    if isinstance(a.val, jax.core.Tracer) or isinstance(b.val, jax.core.Tracer):
        return
    from repro.plan.structure import fingerprint
    got = fingerprint(a, b)
    if got != fp:
        raise ValueError(
            f"stale plan: operands' sparsity fingerprint {got[:12]}… differs "
            f"from the plan's {fp[:12]}… — the pattern the plan's capacities "
            "were sized for changed, which silently truncates or poisons the "
            "output. Rebuild with plan.make_plan/make_dist_plan on the new "
            "operands, or opt out for deliberate cross-pattern reuse with "
            "dataclasses.replace(plan, fp=None) (size slack accordingly)")


def spgemm_coo(a: EllRows, b: EllCols, out_cap="auto", *,
               accumulator: str | None = None, tile: int | None = None,
               check: bool = False, plan=None) -> Coo:
    """Sorted-COO SpGEMM (paper Fig. 7-11 pipeline, single device).

    Prefer ``repro.spgemm(a, b, ...)`` — the unified front door (core/api.py)
    delegates here with identical kwargs.

    ``out_cap`` — static output capacity, or ``'auto'`` to derive it from
    the symbolic phase (plan/symbolic; requires concrete operands).
    ``accumulator`` — ``'sort' | 'tiled' | 'bucket' | 'hash' | 'stream' |
    'search'`` pick a backend directly; ``'auto'`` lets ``plan.make_plan``
    choose one
    (concrete operands). ``'stream'`` skips the monolithic SCCP multiply
    entirely and scans A slabs (core.streaming), bounding the intermediate
    working set to O(n·k_b + stream_cap). A pre-built ``plan`` (repro.plan.Plan) supplies out_cap,
    backend and all blocking sizes — explicitly passed arguments still win —
    and keeps this call jit/vmap-compatible: every Plan field is a Python
    int. With neither plan nor accumulator given the backend defaults to
    ``'sort'`` even when ``out_cap='auto'`` sizes the output symbolically;
    only an explicit ``'auto'`` (or a plan) opts into backend selection.
    ``check=True`` routes the result through ``check_no_overflow`` (host
    sync; call outside jit) so truncation or backend drops raise instead of
    returning silently-wrong output.
    """
    if plan is not None:
        _validate_plan_fp(plan, a, b)
    if plan is None and (out_cap == "auto" or accumulator == "auto"):
        if isinstance(a.val, jax.core.Tracer):
            raise ValueError(
                "out_cap='auto'/accumulator='auto' plan from operand VALUES, "
                "which jit/vmap abstract away — build the plan outside the "
                "trace (plan.make_plan on concrete operands) and pass plan=, "
                "or pass a concrete out_cap")
        from repro.plan import make_plan
        # Oversized coordinate spaces force the unpacked 'sort' path below;
        # request that from the planner too so sizing-only calls with a
        # pinned packed-key backend don't spuriously reject.
        oversized = a.n_rows * b.n_cols >= jnp.iinfo(jnp.int32).max
        plan = make_plan(
            a, b,
            out_cap=None if out_cap == "auto" else out_cap,
            backend=("sort" if accumulator is None or oversized else
                     None if accumulator == "auto" else accumulator))
    if plan is not None:
        out_cap = plan.out_cap if out_cap == "auto" else out_cap
        accumulator = plan.backend if accumulator in (None, "auto") \
            else accumulator
        tile = plan.tile if tile is None else tile
    accumulator = accumulator or "sort"
    tile = tile or 4096
    if accumulator not in ("sort", "tiled", "bucket", "hash", "stream",
                           "search"):
        raise ValueError(f"unknown accumulator {accumulator!r}")
    if a.n_rows * b.n_cols >= jnp.iinfo(jnp.int32).max:
        # Packed int32 keys can't span this coordinate space (the tiled /
        # bucket / hash / stream / search backends all key on
        # row*n_cols+col); the two-key lexicographic sort path is the only
        # lossless realization.
        accumulator = "sort"

    if accumulator == "stream":
        # The whole point: never materialize the (k_a, n, k_b) stream.
        from .streaming import spgemm_coo_stream
        scap = plan.stream_cap if plan is not None else None
        grp = plan.stream_group if plan is not None else 1
        if _obs.is_enabled():
            with _obs.span("spgemm.accumulate", backend="stream",
                           lanes=a.k * a.n_cols * b.k,
                           out_cap=int(out_cap)) as sp:
                coo = spgemm_coo_stream(a, b, out_cap, stream_cap=scap,
                                        group=grp)
                _obs.sync(coo.val)
            if sp.dur_us is not None \
                    and not isinstance(a.val, jax.core.Tracer):
                _obs_metrics.record_backend_us(
                    _plan_key(plan, a.n_rows, b.n_cols), "stream", sp.dur_us)
        else:
            coo = spgemm_coo_stream(a, b, out_cap, stream_cap=scap, group=grp)
    elif _obs.is_enabled():
        with _obs.span("spgemm.multiply", backend=accumulator,
                       k_a=a.k, k_b=b.k, n=a.n_cols):
            val, row, col = sccp_multiply(a, b)
            _obs.sync(val)
        coo = accumulate_stream(row, col, val, out_cap, a.n_rows, b.n_cols,
                                backend=accumulator, tile=tile, plan=plan)
    else:
        val, row, col = sccp_multiply(a, b)
        coo = accumulate_stream(row, col, val, out_cap, a.n_rows, b.n_cols,
                                backend=accumulator, tile=tile, plan=plan)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


def spgemm_dense(a: EllRows, b: EllCols) -> jax.Array:
    """Dense-output SpGEMM via the same structured multiply."""
    val, row, col = sccp_multiply(a, b)
    return scatter_dense(row, col, val, a.n_rows, b.n_cols)


def spgemm_streaming(a: EllRows, b: EllCols) -> jax.Array:
    """Scan over A slabs (one Fig.-8 iteration per step) accumulating dense C.

    Matches the hardware schedule: each ring step materializes only the
    (n, k_b) intermediate of the current slab pair batch.
    """
    n_rows, n_cols = a.n_rows, b.n_cols

    def step(c_acc, i):
        val, row, col = sccp_multiply_slab(a, b, i)
        c_acc = c_acc + scatter_dense(row, col, val, n_rows, n_cols)
        return c_acc, ()

    init = jnp.zeros((n_rows, n_cols), a.val.dtype)
    c, _ = jax.lax.scan(step, init, jnp.arange(a.k))
    return c


def spgemm_coo_batched(a: EllRows, b: EllCols, out_cap="auto", *,
                       accumulator: str | None = None, tile: int | None = None,
                       check: bool = False, plan=None) -> Coo:
    """Batched C[i] = A[i]·B[i]: ELLPACK planes carry a leading batch axis
    (shared n_rows/n_cols/k/caps). Prefer ``repro.spgemm`` — it detects the
    batch axis and delegates here with identical kwargs. Returns a ``Coo``
    whose leaves — including
    ``ngroups`` — have the batch as their leading axis. ``accumulator`` must
    be a concrete backend or come from a ``plan`` (built with
    ``plan.make_plan`` on a representative slice): 'auto' planning inspects
    operand values, which vmap abstracts away. ``check`` runs once on the
    batched result (host sync, outside the vmap)."""
    if plan is None and (accumulator == "auto" or out_cap == "auto"):
        raise ValueError("batched spgemm needs a concrete out_cap/backend: "
                         "build one with plan.make_plan on a representative "
                         "slice and pass plan=")
    fn = partial(spgemm_coo, out_cap=out_cap, accumulator=accumulator,
                 tile=tile, plan=plan)
    coo = jax.vmap(fn)(a, b)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


def _coo_from_slots(key: jax.Array, sums: jax.Array, nnz: jax.Array, *,
                    out_cap: int, n_rows: int, n_cols: int) -> Coo:
    """Dress segment-summed slot values in the sorted-COO output contract:
    coordinates come straight from the precomputed unique keys, pad slots
    (beyond the structure's true nnz) get the row = col = -1 / val = 0
    convention, and ``ngroups`` is the structure's exact group count."""
    ok = jnp.arange(out_cap, dtype=jnp.int32) < nnz
    row = jnp.where(ok, (key // n_cols).astype(jnp.int32), INVALID)
    col = jnp.where(ok, (key % n_cols).astype(jnp.int32), INVALID)
    val = jnp.where(ok, sums, 0)
    return Coo(row=row, col=col, val=val, shape=(n_rows, n_cols),
               ngroups=nnz.astype(jnp.int32))


@partial(jax.jit, static_argnames=("out_cap", "n_rows", "n_cols"))
def _numeric_scatter(row: jax.Array, col: jax.Array, val: jax.Array,
                     key: jax.Array, nnz: jax.Array, *, out_cap: int,
                     n_rows: int, n_cols: int) -> Coo:
    """Numeric-phase core: binary-search each product's packed key into the
    precomputed sorted unique keys, one segment-sum into the slots. No
    planning, no coordinate sort — O(p log u) search + O(p) sum. Invalid
    lanes land in the discarded dump slot; a VALID product whose key is
    absent from the structure (a stale structure used with
    ``validate=False``) lands there too, and its value is lost — so such
    misses poison ``Coo.ngroups`` past ``out_cap`` exactly like a backend
    drop, never passing for a clean result."""
    row, col, val = row.reshape(-1), col.reshape(-1), val.reshape(-1)
    valid = jnp.logical_and(row >= 0, col >= 0)
    pk = jnp.where(valid,
                   row.astype(jnp.int32) * n_cols + col.astype(jnp.int32),
                   0)
    slot = jnp.searchsorted(key, pk, side="left").astype(jnp.int32)
    miss = jnp.logical_or(~valid, jnp.take(key, jnp.minimum(slot, out_cap - 1),
                                           mode="clip") != pk)
    slot = jnp.where(miss, out_cap, slot)
    n_miss = jnp.sum(jnp.logical_and(valid, miss)).astype(jnp.int32)
    sums = jax.ops.segment_sum(jnp.where(valid, val, 0), slot,
                               num_segments=out_cap + 1)[:out_cap]
    coo = _coo_from_slots(key, sums, nnz, out_cap=out_cap, n_rows=n_rows,
                          n_cols=n_cols)
    return _poison_overflow(coo, n_miss)


@partial(jax.jit, static_argnames=("out_cap", "n_rows", "n_cols", "group"))
def _numeric_stream(a_val, a_idx, b_val, b_idx, key, nnz, *, out_cap: int,
                    n_rows: int, n_cols: int, group: int) -> Coo:
    """Numeric phase for stream-planned structures: scan A slab groups,
    searching/summing each group's products into the slot accumulator — the
    (k_a, n, k_b) stream is never materialized, working set is
    O(group·n·k_b + out_cap), matching the cold stream path's memory
    contract while skipping its compact/merge machinery entirely."""
    from repro.kernels.ops import pad_to
    a_val = pad_to(a_val, 0, group, 0)
    a_idx = pad_to(a_idx, 0, group, INVALID)
    n = a_val.shape[1]
    k_b = b_val.shape[1]

    def step(carry, g):
        acc, nm = carry
        av = jax.lax.dynamic_slice_in_dim(a_val, g * group, group, axis=0)
        ai = jax.lax.dynamic_slice_in_dim(a_idx, g * group, group, axis=0)
        v = (av[:, :, None] * b_val[None, :, :]).reshape(-1)
        r = jnp.broadcast_to(ai[:, :, None], (group, n, k_b)).reshape(-1)
        c = jnp.broadcast_to(b_idx[None, :, :], (group, n, k_b)).reshape(-1)
        valid = jnp.logical_and(r >= 0, c >= 0)
        pk = jnp.where(valid, r * n_cols + c, 0).astype(jnp.int32)
        slot = jnp.searchsorted(key, pk, side="left").astype(jnp.int32)
        miss = jnp.logical_or(
            ~valid, jnp.take(key, jnp.minimum(slot, out_cap - 1),
                             mode="clip") != pk)
        slot = jnp.where(miss, out_cap, slot)
        nm = nm + jnp.sum(jnp.logical_and(valid, miss)).astype(jnp.int32)
        acc = acc + jax.ops.segment_sum(jnp.where(valid, v, 0), slot,
                                        num_segments=out_cap + 1)
        return (acc, nm), ()

    init = (jnp.zeros((out_cap + 1,),
                      jnp.result_type(a_val.dtype, b_val.dtype)),
            jnp.int32(0))
    (acc, n_miss), _ = jax.lax.scan(step, init,
                                    jnp.arange(a_val.shape[0] // group))
    coo = _coo_from_slots(key, acc[:out_cap], nnz, out_cap=out_cap,
                          n_rows=n_rows, n_cols=n_cols)
    return _poison_overflow(coo, n_miss)


def spgemm_coo_numeric(a: EllRows, b: EllCols, structure, *,
                       check: bool = False, validate: bool = True) -> Coo:
    """Numeric phase of the two-phase SpGEMM: multiply + scatter into a
    precomputed ``SpgemmStructure`` (plan.make_structure), skipping planning
    and coordinate sorting entirely. Prefer ``repro.spgemm(a, b,
    structure=st)`` — the unified front door delegates here.

    The result is bit-identical to the cold ``spgemm_coo`` on the operands
    the structure was built from, up to floating-point summation order (the
    slot segment-sum fixes one canonical order; backends differ only in
    rounding). Repeat calls with the same shapes hit XLA's compile cache —
    the intended serving pattern: one symbolic call, thousands of numeric
    calls. Structures from stream-backed plans scan A slab groups so the
    product stream is never materialized (same memory contract as the cold
    stream path). ``validate=False`` skips the fingerprint check (e.g. under
    jit, or deliberate reuse across value-only updates — which is exactly
    what the fingerprint permits anyway); a stale structure then routes
    unknown keys to the discarded overflow slot AND poisons ``Coo.ngroups``
    past ``out_cap`` — their values are lost, so ``overflowed()`` flags it
    and ``check=True`` raises instead of returning silently-wrong output.
    ``check=True`` otherwise runs the usual overflow check for API parity
    (a correctly built structure cannot overflow or miss)."""
    if validate:
        structure.validate(a, b)
    if a.val.ndim != 2:
        raise ValueError("batched operands: use spgemm_coo_numeric_batched "
                         "with a structure from make_structure_batched")
    st = structure
    plan = st.plan
    backend = plan.backend if plan is not None else "sort"
    sp = (_obs.span("spgemm.numeric", backend=backend, out_cap=st.out_cap,
                    n_rows=st.n_rows, n_cols=st.n_cols)
          if _obs.is_enabled() else _obs.NULL_SPAN)
    with sp:
        if plan is not None and plan.backend == "stream":
            grp = max(1, min(plan.stream_group, a.val.shape[0]))
            coo = _numeric_stream(a.val, a.idx, b.val, b.idx, st.key, st.nnz,
                                  out_cap=st.out_cap, n_rows=st.n_rows,
                                  n_cols=st.n_cols, group=grp)
        else:
            val, row, col = sccp_multiply(a, b)
            coo = _numeric_scatter(row, col, val, st.key, st.nnz,
                                   out_cap=st.out_cap, n_rows=st.n_rows,
                                   n_cols=st.n_cols)
        _obs.sync(coo.val)
        if _obs.is_enabled() and not isinstance(coo.ngroups, jax.core.Tracer):
            ng = int(coo.ngroups)
            sp.set(nnz=ng)
            if ng > st.out_cap:
                # structure-miss drop → _poison_overflow stamped ngroups
                _obs_metrics.inc("spgemm.poison_events")
                _obs.instant("spgemm.poison", backend=backend, ngroups=ng,
                             cap=int(st.out_cap))
    if sp.dur_us is not None and not isinstance(a.val, jax.core.Tracer):
        _obs_metrics.observe(f"numeric_us.{backend}", sp.dur_us)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


def spgemm_coo_numeric_batched(a: EllRows, b: EllCols, structure, *,
                               check: bool = False,
                               validate: bool = True) -> Coo:
    """Batched numeric phase: vmap the slot scatter over the leading batch
    axis of both operands and of the structure's per-element keys/nnz
    (plan.make_structure_batched). Prefer ``repro.spgemm(a, b,
    structure=st)`` — it detects batched structures and delegates here.
    Shares ``spgemm_coo_numeric``'s
    contract; ``check`` runs once on the batched result."""
    if validate:
        structure.validate(a, b)
    if not structure.batched:
        raise ValueError("structure is unbatched — build one with "
                         "plan.make_structure_batched for batched operands")
    st = structure

    def one(a_i, b_i, key, nnz):
        val, row, col = sccp_multiply(a_i, b_i)
        return _numeric_scatter(row, col, val, key, nnz, out_cap=st.out_cap,
                                n_rows=st.n_rows, n_cols=st.n_cols)

    coo = jax.vmap(one)(a, b, st.key, st.nnz)
    if check:
        from .accumulate import check_no_overflow
        coo = check_no_overflow(coo)
    return coo


def spgemm_dense_batched(a: EllRows, b: EllCols) -> jax.Array:
    """Batched dense-output SpGEMM over a leading batch axis."""
    return jax.vmap(spgemm_dense)(a, b)


@partial(jax.jit, static_argnames=("k_a", "k_b", "out_cap"))
def spgemm_from_dense(a_dense: jax.Array, b_dense: jax.Array,
                      k_a: int, k_b: int, out_cap: int) -> Coo:
    """Convenience: dense inputs → ELLPACK → SPLIM SpGEMM → sorted COO."""
    a = ell_rows_from_dense(a_dense, k_a)
    b = ell_cols_from_dense(b_dense, k_b)
    return spgemm_coo(a, b, out_cap)


def spmm_ell_dense(a: EllRows, x: jax.Array) -> jax.Array:
    """C = A @ X with A in row-wise ELLPACK and X dense (n, d).

    The structured-multiply half of SCCP with a *structured* output: each
    product lane A.val[s, c] * X[c, :] scatter-adds into output row
    A.idx[s, c]. One segment-sum per slab; no decompression of A.
    This is the op behind MoE dispatch/combine (models/moe.py) and
    SparseLinear. kernels/ell_spmm.py is the Pallas version.
    """
    k, n = a.val.shape
    d = x.shape[-1]
    rows = jnp.where(a.idx >= 0, a.idx, a.n_rows).reshape(-1)        # (k*n,)
    contrib = (a.val[:, :, None] * x[None, :, :]).reshape(-1, d)      # (k*n, d)
    out = jax.ops.segment_sum(contrib, rows, num_segments=a.n_rows + 1)
    return out[: a.n_rows]


def spmm_dense_ell(x: jax.Array, b: EllCols) -> jax.Array:
    """C = X @ B with X dense (d, n) and B in column-wise ELLPACK."""
    n, k = b.val.shape
    d = x.shape[0]
    cols = jnp.where(b.idx >= 0, b.idx, b.n_cols).reshape(-1)         # (n*k,)
    contrib = (x[:, :, None] * b.val[None, :, :]).reshape(d, -1)      # (d, n*k)
    out = jax.ops.segment_sum(contrib.T, cols, num_segments=b.n_cols + 1)
    return out[: b.n_cols].T
