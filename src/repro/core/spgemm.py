"""End-to-end SPLIM SpGEMM: SCCP multiply → in-situ-search-style accumulate.

Public entry points:

  * ``spgemm_coo``      — C = A·B as sorted COO (the paper's output format).
                          ``accumulator='sort'`` uses the global
                          ``jax.lax.sort`` path; ``'tiled'`` routes through
                          the multi-tile bitonic merge tree
                          (kernels.ops.sort_merge) so the product stream
                          never has to fit one monolithic sort.
  * ``spgemm_dense``    — C dense (oracle / small-n convenience).
  * ``spgemm_streaming``— scan over A slabs so the intermediate working set is
                          O(n·k_b) (paper's Fig. 8 iteration + BSS memory
                          argument), scatter-accumulating into dense C.
  * ``spgemm_coo_batched`` / ``spgemm_dense_batched`` — vmap over a leading
                          batch axis of both ELLPACK operands (all shapes /
                          caps shared across the batch).
  * ``spmm_ell_dense``  — ELLPACK × dense matrix (powers MoE dispatch and
                          SparseLinear in the LM stack).

All are jittable with static k / caps, and the single-matrix entry points
are vmap-able (the batched wrappers are exactly that).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .accumulate import accumulate, scatter_dense
from .formats import (INVALID, Coo, EllCols, EllRows, ell_cols_from_dense,
                      ell_rows_from_dense)
from .sccp import sccp_multiply, sccp_multiply_slab


def _coo_from_merged(key: jax.Array, tot: jax.Array, out_cap: int,
                     n_rows: int, n_cols: int) -> Coo:
    """Compact a sort_merge stream (sorted keys, run-tail totals) to COO.

    O(n) scatter — tails are already in ascending key order, so a cumsum
    gives each one its output slot directly (no global sort: that would
    reintroduce the monolithic pass the merge tree exists to avoid).
    Non-tail lanes and overflow groups park in the discarded dump slot.
    """
    from repro.kernels.bitonic_merge import KEY_INVALID
    nxt = jnp.concatenate([key[1:], jnp.full((1,), KEY_INVALID - 1, key.dtype)])
    tail = jnp.logical_and(key != nxt, key != KEY_INVALID)
    ngroups = jnp.sum(tail)
    dst = jnp.where(tail, jnp.cumsum(tail) - 1, out_cap)
    dst = jnp.minimum(dst, out_cap)
    row = (jnp.full((out_cap + 1,), INVALID, jnp.int32)
           .at[dst].set((key // n_cols).astype(jnp.int32)))[:out_cap]
    col = (jnp.full((out_cap + 1,), INVALID, jnp.int32)
           .at[dst].set((key % n_cols).astype(jnp.int32)))[:out_cap]
    val = jnp.zeros((out_cap + 1,), tot.dtype).at[dst].set(tot)[:out_cap]
    return Coo(row=row, col=col, val=val, shape=(n_rows, n_cols),
               ngroups=ngroups.astype(jnp.int32))


def spgemm_coo(a: EllRows, b: EllCols, out_cap: int, *,
               accumulator: str = "sort", tile: int = 4096) -> Coo:
    """Sorted-COO SpGEMM (paper Fig. 7-11 pipeline, single device)."""
    val, row, col = sccp_multiply(a, b)
    if accumulator == "tiled":
        from repro.kernels import ops
        key, tot = ops.sort_merge(row, col, val, a.n_rows, b.n_cols, tile=tile)
        return _coo_from_merged(key, tot, out_cap, a.n_rows, b.n_cols)
    if accumulator != "sort":
        raise ValueError(f"unknown accumulator {accumulator!r}")
    return accumulate(row, col, val, out_cap, a.n_rows, b.n_cols)


def spgemm_dense(a: EllRows, b: EllCols) -> jax.Array:
    """Dense-output SpGEMM via the same structured multiply."""
    val, row, col = sccp_multiply(a, b)
    return scatter_dense(row, col, val, a.n_rows, b.n_cols)


def spgemm_streaming(a: EllRows, b: EllCols) -> jax.Array:
    """Scan over A slabs (one Fig.-8 iteration per step) accumulating dense C.

    Matches the hardware schedule: each ring step materializes only the
    (n, k_b) intermediate of the current slab pair batch.
    """
    n_rows, n_cols = a.n_rows, b.n_cols

    def step(c_acc, i):
        val, row, col = sccp_multiply_slab(a, b, i)
        c_acc = c_acc + scatter_dense(row, col, val, n_rows, n_cols)
        return c_acc, ()

    init = jnp.zeros((n_rows, n_cols), a.val.dtype)
    c, _ = jax.lax.scan(step, init, jnp.arange(a.k))
    return c


def spgemm_coo_batched(a: EllRows, b: EllCols, out_cap: int, *,
                       accumulator: str = "sort", tile: int = 4096) -> Coo:
    """Batched C[i] = A[i]·B[i]: ELLPACK planes carry a leading batch axis
    (shared n_rows/n_cols/k/caps). Returns a ``Coo`` whose leaves — including
    ``ngroups`` — have the batch as their leading axis."""
    fn = partial(spgemm_coo, out_cap=out_cap, accumulator=accumulator,
                 tile=tile)
    return jax.vmap(fn)(a, b)


def spgemm_dense_batched(a: EllRows, b: EllCols) -> jax.Array:
    """Batched dense-output SpGEMM over a leading batch axis."""
    return jax.vmap(spgemm_dense)(a, b)


@partial(jax.jit, static_argnames=("k_a", "k_b", "out_cap"))
def spgemm_from_dense(a_dense: jax.Array, b_dense: jax.Array,
                      k_a: int, k_b: int, out_cap: int) -> Coo:
    """Convenience: dense inputs → ELLPACK → SPLIM SpGEMM → sorted COO."""
    a = ell_rows_from_dense(a_dense, k_a)
    b = ell_cols_from_dense(b_dense, k_b)
    return spgemm_coo(a, b, out_cap)


def spmm_ell_dense(a: EllRows, x: jax.Array) -> jax.Array:
    """C = A @ X with A in row-wise ELLPACK and X dense (n, d).

    The structured-multiply half of SCCP with a *structured* output: each
    product lane A.val[s, c] * X[c, :] scatter-adds into output row
    A.idx[s, c]. One segment-sum per slab; no decompression of A.
    This is the op behind MoE dispatch/combine (models/moe.py) and
    SparseLinear. kernels/ell_spmm.py is the Pallas version.
    """
    k, n = a.val.shape
    d = x.shape[-1]
    rows = jnp.where(a.idx >= 0, a.idx, a.n_rows).reshape(-1)        # (k*n,)
    contrib = (a.val[:, :, None] * x[None, :, :]).reshape(-1, d)      # (k*n, d)
    out = jax.ops.segment_sum(contrib, rows, num_segments=a.n_rows + 1)
    return out[: a.n_rows]


def spmm_dense_ell(x: jax.Array, b: EllCols) -> jax.Array:
    """C = X @ B with X dense (d, n) and B in column-wise ELLPACK."""
    n, k = b.val.shape
    d = x.shape[0]
    cols = jnp.where(b.idx >= 0, b.idx, b.n_cols).reshape(-1)         # (n*k,)
    contrib = (x[:, :, None] * b.val[None, :, :]).reshape(d, -1)      # (d, n*k)
    out = jax.ops.segment_sum(contrib.T, cols, num_segments=b.n_cols + 1)
    return out[: b.n_cols].T
