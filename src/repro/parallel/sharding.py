"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code annotates tensors with *logical* axis names ("batch", "ff",
"heads", "expert", …). A ``ShardingRules`` maps logical names to mesh axes;
resolution drops any axis whose dimension is not divisible by the mesh axis
size (e.g. yi-34b's 56 heads on a 16-way model axis) instead of failing —
the tensor is then replicated along that mesh axis and the roofline analysis
surfaces the cost. This keeps every (arch × mesh) cell compilable, which is
the dry-run contract.

Rules are threaded through a context manager so the same model code runs
unsharded on CPU smoke tests and fully sharded under the production mesh.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical -> mesh mapping for the production mesh (DESIGN.md §5).
# "batch"-like axes go to data(+pod) parallelism; width-like axes to tensor
# parallelism. "seq_shard" is used only by the sequence-parallel long-context
# paths; "expert" by MoE expert parallelism.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "ff": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "qkv_flat": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "expert_ff": ("model",),
    "seq_shard": ("model",),   # decode KV-cache sequence axis (flash-decode)
    "seq_act": ("model",),     # Megatron-SP: residual-stream seq sharding
    "fsdp": ("data",),         # ZeRO-3: weights sharded over the data axis
    "opt_shard": ("data",),    # ZeRO-1: optimizer state sharded over data
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Optional[Mesh]
    rules: Dict[str, Tuple[str, ...]]

    def axis_size(self, mesh_axis: str) -> int:
        if self.mesh is None or mesh_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[mesh_axis]

    def resolve(self, logical_axes: Sequence[Optional[str]],
                shape: Sequence[int]) -> P:
        """Logical axes -> PartitionSpec, dropping non-divisible mappings."""
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        used: set = set()
        parts = []
        for dim, name in zip(shape, logical_axes):
            if name is None or self.mesh is None:
                parts.append(None)
                continue
            mesh_axes = self.rules.get(name, ())
            chosen = []
            size = 1
            for ax in mesh_axes:
                if ax in used or ax not in self.mesh.shape:
                    continue
                nxt = size * self.mesh.shape[ax]
                if dim % nxt == 0:
                    chosen.append(ax)
                    size = nxt
            if chosen:
                used.update(chosen)
                parts.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
            else:
                parts.append(None)
        return P(*parts)


_state = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(mesh: Optional[Mesh],
                   rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = getattr(_state, "rules", None)
    _state.rules = ShardingRules(mesh, dict(rules or DEFAULT_RULES))
    try:
        yield _state.rules
    finally:
        _state.rules = prev


def logical_to_pspec(logical_axes: Sequence[Optional[str]],
                     shape: Sequence[int]) -> P:
    r = current_rules()
    if r is None or r.mesh is None:
        return P()
    return r.resolve(logical_axes, shape)


def maybe_shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.resolve(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def named_sharding(logical_axes: Sequence[Optional[str]],
                   shape: Sequence[int]) -> Optional[NamedSharding]:
    r = current_rules()
    if r is None or r.mesh is None:
        return None
    return NamedSharding(r.mesh, r.resolve(logical_axes, shape))


# ---------------------------------------------------------------------------
# Distributed SpGEMM operand sharding (core/distributed.spgemm_coo_sharded)
# ---------------------------------------------------------------------------

def spgemm_operand_specs(axis: str, *, schedule: str = "ring",
                         batched: bool = False) -> Tuple[P, P]:
    """PartitionSpecs for (A, B) ELLPACK planes under a distributed schedule.

    B slabs are always sharded over ``axis`` (they rotate); A slabs are
    sharded under the B-stationary ``'ring'`` and 2D ``'summa'`` schedules
    (summa's logical pr × pc grid lives *on top of* the same flat 1D slab
    sharding — row/column panels are index arithmetic over shard blocks, so
    operands need no resharding to switch schedules) and replicated under
    C-stationary ``'cstat'`` (every device masks A to its owned row block).
    ``batched`` prepends an unsharded batch dim.
    """
    lead = (None,) if batched else ()
    spec_b = P(*lead, None, axis)
    spec_a = P(*lead, None, None) if schedule == "cstat" else P(*lead, axis, None)
    return spec_a, spec_b


def put_spgemm_operands(a, b, mesh: Mesh, axis: str, *,
                        schedule: str = "ring"):
    """``device_put`` ELLPACK operands with the slab sharding
    ``spgemm_coo_sharded`` expects, pre-padded to the ring size — placing
    operands up front avoids a resharding collective at dispatch time.
    Returns the (possibly padded) ``(EllRows, EllCols)`` pair.
    """
    from repro.core.distributed import pad_slabs_a, pad_slabs_b
    from repro.core.formats import EllCols, EllRows
    n_dev = mesh.shape[axis]
    a = pad_slabs_a(a, n_dev)
    b = pad_slabs_b(b, n_dev)
    spec_a, spec_b = spgemm_operand_specs(axis, schedule=schedule,
                                          batched=a.val.ndim == 3)
    sh_a, sh_b = NamedSharding(mesh, spec_a), NamedSharding(mesh, spec_b)
    return (EllRows(val=jax.device_put(a.val, sh_a),
                    idx=jax.device_put(a.idx, sh_a), n_rows=a.n_rows),
            EllCols(val=jax.device_put(b.val, sh_b),
                    idx=jax.device_put(b.idx, sh_b), n_cols=b.n_cols))


def axis_size(logical_name: str) -> int:
    """Product of mesh-axis sizes a logical axis maps to (1 without mesh)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return 1
    total = 1
    for ax in r.rules.get(logical_name, ()):
        total *= r.axis_size(ax)
    return total
