"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

``pipeline_apply`` runs ``n_stages`` stage functions over microbatches with
the classic fill/drain schedule, expressed as a shard_map over the ``pipe``
axis: every device holds one stage's params; microbatch activations move
stage→stage with ``ppermute`` (the same neighbour-only pattern as SPLIM's
ring broadcast — DESIGN.md §2). Bubble fraction = (S-1)/(M+S-1).

The production dry-runs use DP×TP (PP off by default); this module is the
composable PP building block, exercised by tests/test_pipeline.py on 8 fake
devices.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pvary, shard_map


def pipeline_apply(stage_fn: Callable, params_stacked, x_microbatches,
                   mesh: Mesh, axis: str = "pipe"):
    """Run a homogeneous-stage pipeline.

    stage_fn(params_slice, x) -> x      one stage's computation
    params_stacked: leaves (n_stages, ...) sharded over ``axis``
    x_microbatches: (n_micro, mb, ...) replicated input microbatches
    Returns (n_micro, mb, ...) outputs after all stages.
    """
    n_stages = mesh.shape[axis]

    def shard_fn(params_local, xs):
        # params_local: (1, ...) this stage's params; xs: (n_micro, mb, ...)
        p = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        n_micro = xs.shape[0]
        total = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if any); others use the buffer
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(stage == 0, xs[inject], buf)
            y = stage_fn(p, x_in)
            # valid iff this stage is processing microbatch m = t - stage
            m = t - stage
            valid = jnp.logical_and(m >= 0, m < n_micro)
            y = jnp.where(valid, y, buf)
            # last stage records its finished microbatch
            outs = jax.lax.cond(
                jnp.logical_and(valid, stage == n_stages - 1),
                lambda o: o.at[jnp.clip(m, 0, n_micro - 1)].set(y),
                lambda o: o, outs)
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return (buf, outs), ()

        (buf, outs), _ = jax.lax.scan(
            tick, (pvary(buf, axis), pvary(outs, axis)),
            jnp.arange(total))
        # outs live on the last stage; broadcast to all for a replicated out
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P())
    return fn(params_stacked, x_microbatches)
