"""Parallelism substrate: logical-axis sharding rules, collectives, pipeline."""
from . import sharding
from .sharding import (axis_size, logical_to_pspec, maybe_shard,
                       sharding_rules, current_rules, ShardingRules)

__all__ = ["sharding", "axis_size", "logical_to_pspec", "maybe_shard",
           "sharding_rules", "current_rules", "ShardingRules"]
