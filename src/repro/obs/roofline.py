"""Measured-vs-modeled roofline per accumulation backend.

Closes the observability loop: span timings (obs/trace) are joined against
the planner's modeled intermediate traffic (``Plan.est["interm_*"]``, built
from ``core/hwmodel.MatrixStats``) to express each backend's achieved
bandwidth as a fraction of what this host can actually stream.

Three pieces:

* :func:`modeled_bytes` — the memory traffic the cost model says one
  ``spgemm_coo`` call with a given backend moves: operand lanes in, the
  materialized intermediate (the ``interm_<backend>`` term the planner
  already scores), and the COO output out.
* :func:`measure_reference_bw` — a self-calibrating bandwidth anchor: a
  jitted elementwise copy over a ~16 MiB buffer, timed on this host. Using
  a measured anchor (instead of a hard-coded peak) makes the derived
  fraction machine-independent enough to gate in CI: a backend that moves
  its modeled bytes slower than a plain streaming copy lands in (0, 1),
  and nothing real lands much above 1.
* :func:`measure_roofline` — times each backend's jitted ``spgemm_coo``
  through a ``roofline.measure`` span (tracer temporarily enabled if off,
  so the timings ARE span timings) and returns per-backend
  ``{us, modeled_bytes, modeled_flops, achieved_bw, ref_bw, frac}``.

``frac`` = achieved_bw / ref_bw ∈ (0, 1.5] is the CI gate: at smoke scale
dispatch overhead dominates so fractions sit well under 1; values above
1.5 would mean the model's byte count is inconsistent with physics (or the
timer broke), which is exactly what the gate is for.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Sequence

from . import trace as _trace

# ~16 MiB of f32 — big enough to stream from memory, small enough for CI.
_REF_ELEMS = 4 * 1024 * 1024


def modeled_bytes(plan, backend: str, *, nnz_a: int, nnz_b: int) -> float:
    """Modeled memory traffic of one spgemm_coo call for ``backend``.

    Operands: 8 B per stored lane (f32 value + i32 index). Intermediate:
    the planner's ``interm_<backend>`` estimate — the materialized
    un-accumulated product stream (or the streaming engine's bounded
    working set). Output: 12 B per COO coordinate (row + col + val).
    Falls back to operands+output when the plan carries no estimates
    (hand-built plans).
    """
    est = plan.est or {}
    interm = float(est.get(f"interm_{backend}", 0.0))
    return 8.0 * (nnz_a + nnz_b) + interm + 12.0 * float(plan.out_cap)


def measure_reference_bw(elems: int = _REF_ELEMS, iters: int = 8) -> float:
    """Measured streaming bandwidth of this host, bytes/s.

    One jitted elementwise multiply over ``elems`` f32: reads 4·elems,
    writes 4·elems → 8·elems bytes per call.
    """
    import jax
    import jax.numpy as jnp
    x = jnp.arange(elems, dtype=jnp.float32)
    f = jax.jit(lambda v: v * jnp.float32(1.0000001))
    f(x).block_until_ready()                      # compile outside timing
    t0 = time.perf_counter()
    for _ in range(iters):
        f(x).block_until_ready()
    dt = max(1e-9, (time.perf_counter() - t0) / iters)
    return 8.0 * elems / dt


def measure_roofline(a, b, *, plan=None,
                     backends: Optional[Sequence[str]] = None,
                     iters: int = 3, warmup: int = 1,
                     ref_bw: Optional[float] = None) -> Dict[str, Dict]:
    """Per-backend achieved-vs-modeled bandwidth on one operand pair.

    Times ``iters`` jitted ``spgemm_coo`` calls per backend inside a
    ``roofline.measure`` span (the tracer is enabled for the duration if it
    was off, and restored after), then joins ``Span.dur_us`` against
    :func:`modeled_bytes`. Operands must be concrete.
    """
    import jax
    from repro.core.spgemm import spgemm_coo
    from repro.plan.planner import BACKENDS, make_plan
    if plan is None:
        plan = make_plan(a, b)
    if backends is None:
        backends = BACKENDS
    if ref_bw is None:
        ref_bw = measure_reference_bw()
    nnz_a = int(jax.device_get((a.idx >= 0).sum()))
    nnz_b = int(jax.device_get((b.idx >= 0).sum()))
    flops = 2.0 * float((plan.stats.valid_products
                         if plan.stats is not None else 0))
    was_on = _trace.is_enabled()
    if not was_on:
        _trace.enable()
    out: Dict[str, Dict] = {}
    try:
        for bk in backends:
            p = dataclasses.replace(plan, backend=bk)
            f = jax.jit(functools.partial(spgemm_coo, out_cap=plan.out_cap,
                                          accumulator=bk, plan=p))
            for _ in range(max(1, warmup)):
                jax.block_until_ready(f(a, b).val)
            with _trace.span("roofline.measure", backend=bk,
                             iters=iters) as sp:
                for _ in range(iters):
                    jax.block_until_ready(f(a, b).val)
            t_us = max(1e-3, (sp.dur_us or 0.0) / max(1, iters))
            mbytes = modeled_bytes(plan, bk, nnz_a=nnz_a, nnz_b=nnz_b)
            achieved = mbytes / (t_us * 1e-6)
            out[bk] = {
                "us": t_us,
                "modeled_bytes": mbytes,
                "modeled_flops": flops,
                "achieved_bw": achieved,
                "achieved_flops": flops / (t_us * 1e-6),
                "ref_bw": ref_bw,
                "frac": achieved / ref_bw,
            }
    finally:
        if not was_on:
            _trace.disable()
    return out
