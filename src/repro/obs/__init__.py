"""repro.obs — zero-dependency tracing + metrics for the SpGEMM stack.

Disabled by default and free when disabled; ``repro.obs.enable()`` turns on
span recording (trace.py), counters/planner-evidence (metrics.py), and the
roofline join (roofline.py, imported lazily to keep ``repro.core`` import
order acyclic).

    import repro.obs as obs
    obs.enable()
    c = spgemm_coo(a, b)                  # instrumented library call
    obs.export_chrome("trace.json")       # Perfetto / chrome://tracing
    obs.snapshot()["metrics"]["planner"]  # est-vs-measured per plan
"""
from __future__ import annotations

from typing import Any, Dict

from . import metrics, trace
from .trace import (NULL_SPAN, Span, Tracer, export_chrome, get_tracer,
                    instant, is_enabled, span, sync)


def enable(reset: bool = False) -> None:
    """Turn on tracing + metrics. ``reset=True`` clears prior recordings."""
    if reset:
        trace.reset()
        metrics.reset()
    trace.enable()


def disable() -> None:
    trace.disable()


def reset() -> None:
    trace.reset()
    metrics.reset()


def snapshot() -> Dict[str, Any]:
    """Combined plain-dict snapshot: ``{"trace": ..., "metrics": ...}``."""
    return {"trace": trace.get_tracer().snapshot(),
            "metrics": metrics.snapshot()}


def __getattr__(name: str):
    if name == "roofline":          # lazy: roofline imports repro.core
        import importlib
        return importlib.import_module(".roofline", __name__)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")


__all__ = [
    "trace", "metrics", "enable", "disable", "reset", "snapshot",
    "span", "sync", "instant", "is_enabled", "export_chrome",
    "get_tracer", "Span", "Tracer", "NULL_SPAN",
]
