"""Zero-dependency runtime tracing for the SpGEMM stack.

One global :class:`Tracer`, **disabled by default**: every instrumentation
point in the library goes through :func:`span` / :func:`instant` /
:func:`sync`, which are true no-ops while disabled — ``span`` returns a
shared singleton context manager (no per-call allocation of trace state),
``sync`` returns its argument untouched, and nothing is ever recorded. The
overhead gate in tests/test_obs.py holds the instrumented hot path to this
contract.

Enabled, the tracer records **host-side wall-clock spans** with proper
nesting (a ``contextvars`` stack, so threads and nested calls interleave
correctly) and explicit **device-sync points**: call sites wrap each phase's
result in :func:`sync`, which blocks until the device work is done before
the span closes — so a span measures compute, not jit dispatch. Under
``jax.jit`` the instrumentation runs once at trace time (spans are tagged
``traced=True`` and never block on tracers); real per-phase numbers come
from calling the instrumented entry points outside jit, or from jitting the
phases separately (obs/roofline.py does exactly that).

Span args are sanitized: numbers/strings/bools pass through, arrays are
reduced to ``dtype+shape`` strings — **matrix values never enter a trace**
(indices/shape metadata only; see README §Observability).

Export: :meth:`Tracer.export_chrome` emits Chrome-trace/Perfetto JSON
(``traceEvents`` with ``ph='X'`` complete events, µs timestamps);
:meth:`Tracer.snapshot` returns the raw span dicts for programmatic joins
(obs/metrics.py and obs/roofline.py consume it).
"""
from __future__ import annotations

import contextvars
import json
import threading
import time
from typing import Any, Dict, List, Optional

MAX_EVENTS = 200_000     # hard buffer bound; beyond it events are counted, not kept

_stack: "contextvars.ContextVar[tuple]" = contextvars.ContextVar(
    "repro_obs_span_stack", default=())


def _clean_args(args: Dict[str, Any]) -> Dict[str, Any]:
    """Sanitize span args: scalars pass, arrays become dtype+shape strings.
    Array *contents* are never recorded (privacy contract)."""
    out: Dict[str, Any] = {}
    for k, v in args.items():
        if v is None or isinstance(v, (bool, int, float, str)):
            out[k] = v
        elif hasattr(v, "item") and getattr(v, "shape", None) == ():
            try:
                out[k] = v.item()
            except Exception:
                out[k] = f"<{type(v).__name__}>"
        else:
            shape = getattr(v, "shape", None)
            dtype = getattr(v, "dtype", "")
            out[k] = (f"<{dtype}{tuple(shape)}>" if shape is not None
                      else f"<{type(v).__name__}>")
    return out


class _NullSpan:
    """Shared do-nothing span returned while tracing is disabled — one
    module-level instance, so a disabled ``span(...)`` allocates no trace
    state whatsoever."""

    __slots__ = ()
    dur_us: Optional[float] = None
    name = ""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **kw):            # parity with Span.set
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One live span. Use as a context manager; ``dur_us`` is readable after
    exit (obs/roofline.py times measurements through it)."""

    __slots__ = ("tracer", "name", "args", "t0", "dur_us", "_token",
                 "parent", "depth", "traced")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any],
                 traced: bool):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.traced = traced
        self.t0 = 0
        self.dur_us: Optional[float] = None
        self.parent: Optional[str] = None
        self.depth = 0

    def set(self, **kw) -> "Span":
        """Attach/override args mid-span (e.g. a result's nnz)."""
        self.args.update(kw)
        return self

    def __enter__(self) -> "Span":
        stack = _stack.get()
        self.parent = stack[-1].name if stack else None
        self.depth = len(stack)
        self._token = _stack.set(stack + (self,))
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        _stack.reset(self._token)
        self.dur_us = (t1 - self.t0) / 1e3
        self.tracer._record(self, t1)
        return False


class Tracer:
    """Thread-safe span/instant recorder (see module docstring)."""

    def __init__(self):
        self._enabled = False
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._dropped = 0
        self._epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------- control

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self, reset: bool = False) -> None:
        if reset:
            self.reset()
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def reset(self) -> None:
        with self._lock:
            self._events = []
            self._dropped = 0
            self._epoch_ns = time.perf_counter_ns()

    # ------------------------------------------------------------ recording

    def span(self, name: str, **args) -> Span:
        traced = bool(args.pop("traced", False)) or _under_jit()
        return Span(self, name, _clean_args(args), traced)

    def instant(self, name: str, **args) -> None:
        """Record a point event (chrome ``ph='i'``)."""
        if not self._enabled:
            return
        now = time.perf_counter_ns()
        ev = {"name": name, "ph": "i",
              "ts_us": (now - self._epoch_ns) / 1e3, "dur_us": 0.0,
              "tid": threading.get_ident() & 0xFFFF,
              "depth": len(_stack.get()), "parent": None,
              "args": _clean_args(args)}
        stack = _stack.get()
        if stack:
            ev["parent"] = stack[-1].name
        with self._lock:
            if len(self._events) < MAX_EVENTS:
                self._events.append(ev)
            else:
                self._dropped += 1

    def _record(self, sp: Span, t1_ns: int) -> None:
        if not self._enabled:
            return
        args = sp.args
        if sp.traced:
            args = dict(args, traced=True)
        ev = {"name": sp.name, "ph": "X",
              "ts_us": (sp.t0 - self._epoch_ns) / 1e3,
              "dur_us": (t1_ns - sp.t0) / 1e3,
              "tid": threading.get_ident() & 0xFFFF,
              "depth": sp.depth, "parent": sp.parent, "args": args}
        with self._lock:
            if len(self._events) < MAX_EVENTS:
                self._events.append(ev)
            else:
                self._dropped += 1

    # -------------------------------------------------------------- export

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict copy of every recorded event (programmatic joins)."""
        with self._lock:
            events = [dict(e) for e in self._events]
            dropped = self._dropped
        return {"events": events, "dropped": dropped}

    def spans(self, name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Recorded complete spans, optionally filtered by exact name."""
        snap = self.snapshot()["events"]
        return [e for e in snap
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def export_chrome(self, path: Optional[str] = None,
                      extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON: ``{"traceEvents": [...]}`` with µs
        timestamps. ``extra`` keys (e.g. a metrics snapshot) are merged at
        the top level — trace viewers ignore unknown keys."""
        snap = self.snapshot()
        trace_events = []
        for e in snap["events"]:
            trace_events.append({
                "name": e["name"], "cat": "repro", "ph": e["ph"],
                "ts": e["ts_us"], "dur": e["dur_us"], "pid": 0,
                "tid": e["tid"], "args": e["args"]})
        out: Dict[str, Any] = {"traceEvents": trace_events,
                               "displayTimeUnit": "ms"}
        if snap["dropped"]:
            out["droppedEvents"] = snap["dropped"]
        if extra:
            out.update(extra)
        if path is not None:
            with open(path, "w") as f:
                json.dump(out, f, indent=1)
        return out


_tracer = Tracer()


def get_tracer() -> Tracer:
    return _tracer


def is_enabled() -> bool:
    return _tracer._enabled


def enable(reset: bool = False) -> None:
    _tracer.enable(reset=reset)


def disable() -> None:
    _tracer.disable()


def reset() -> None:
    _tracer.reset()


def _under_jit() -> bool:
    """True while jax is tracing (spans then measure trace time, flagged)."""
    try:
        import jax
        return isinstance(jax.numpy.zeros(()) + 0, jax.core.Tracer)
    except Exception:
        return False


def span(name: str, **args):
    """The library-wide instrumentation point. Disabled: returns the shared
    null span — no state allocated, nothing recorded."""
    if not _tracer._enabled:
        return NULL_SPAN
    return _tracer.span(name, **args)


def instant(name: str, **args) -> None:
    if _tracer._enabled:
        _tracer.instant(name, **args)


def sync(x):
    """Device-sync point: block until ``x``'s arrays are ready — only while
    tracing (so spans measure compute, not dispatch) and only on concrete
    arrays (tracers pass through untouched). Returns ``x``."""
    if not _tracer._enabled:
        return x
    try:
        import jax
        for leaf in jax.tree_util.tree_leaves(x):
            if isinstance(leaf, jax.core.Tracer):
                continue
            blk = getattr(leaf, "block_until_ready", None)
            if blk is not None:
                blk()
    except Exception:
        pass
    return x


def export_chrome(path: Optional[str] = None, extra=None) -> Dict[str, Any]:
    return _tracer.export_chrome(path, extra=extra)
