"""Gated counters / gauges / histograms for the SpGEMM stack.

Shares the enable switch with :mod:`repro.obs.trace`: while tracing is
disabled every recording call is a cheap early-return and the registry
stays empty. Enabled, the library forwards:

- **planner decisions** — ``record_plan`` stores the chosen backend and the
  modeled ``cost_<backend>`` estimates per plan fingerprint; each
  instrumented accumulate records its measured µs via
  ``record_backend_us``. ``snapshot()`` joins the two into a per-plan
  *mispredict ratio*: measured µs of the chosen backend over the best
  measured backend (1.0 = the planner picked the measured winner).
- **StructureCache** hits/misses/evictions/disk_hits/autotunes
  (forwarded from ``plan/cache.py``).
- **overflow / ngroups-poison events** (``check_no_overflow`` increments
  exactly once per offending call).
- **per-schedule modeled comm bytes** from ``core/distributed.py``.
- **serve-engine** per-request queue/compute latency and batch occupancy.

Histograms are streaming (count/total/min/max) — no samples retained.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from . import trace as _trace


class Metrics:
    """Thread-safe metric registry; all recording is gated on the tracer's
    enable switch so a disabled stack does no bookkeeping at all."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, Dict[str, float]] = {}
        # plan fingerprint -> {"backend": str, "est": {...}, "measured_us": {}}
        self._planner: Dict[str, Dict[str, Any]] = {}

    # ----------------------------------------------------------- recording

    def inc(self, name: str, value: float = 1.0) -> None:
        if not _trace.is_enabled():
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + value

    def gauge(self, name: str, value: float) -> None:
        if not _trace.is_enabled():
            return
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        """Streaming histogram update (count/total/min/max)."""
        if not _trace.is_enabled():
            return
        v = float(value)
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                self._hists[name] = {"count": 1, "total": v, "min": v, "max": v}
            else:
                h["count"] += 1
                h["total"] += v
                h["min"] = min(h["min"], v)
                h["max"] = max(h["max"], v)

    def record_plan(self, key: str, backend: str,
                    est: Optional[Dict[str, Any]] = None) -> None:
        """A planner decision: ``key`` is the plan fingerprint (or a shape
        tag), ``backend`` the chosen accumulator, ``est`` the modeled costs
        (only ``cost_*``/``interm_*``/``splim_model_s`` keys are kept)."""
        if not _trace.is_enabled():
            return
        kept = {k: v for k, v in (est or {}).items()
                if k.startswith(("cost_", "interm_", "splim_model"))}
        with self._lock:
            ent = self._planner.setdefault(
                key, {"backend": backend, "est": {}, "measured_us": {}})
            ent["backend"] = backend
            if kept:
                ent["est"] = kept
            self._counters["planner.decisions"] = \
                self._counters.get("planner.decisions", 0.0) + 1
            bk = f"planner.chose.{backend}"
            self._counters[bk] = self._counters.get(bk, 0.0) + 1

    def record_backend_us(self, key: str, backend: str, us: float) -> None:
        """A measured accumulate for plan ``key`` on ``backend`` — the
        'measured' side of est-vs-measured. Keeps the minimum (best) µs."""
        if not _trace.is_enabled():
            return
        with self._lock:
            ent = self._planner.setdefault(
                key, {"backend": None, "est": {}, "measured_us": {}})
            prev = ent["measured_us"].get(backend)
            ent["measured_us"][backend] = \
                us if prev is None else min(prev, us)

    # ------------------------------------------------------------ snapshot

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict copy; per-plan mispredict ratio is computed here
        (measured[chosen] / min(measured)) when ≥2 backends were measured."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: dict(v) for k, v in self._hists.items()}
            planner = {k: {"backend": v["backend"],
                           "est": dict(v["est"]),
                           "measured_us": dict(v["measured_us"])}
                       for k, v in self._planner.items()}
        for ent in planner.values():
            meas = ent["measured_us"]
            chosen = ent["backend"]
            if chosen in meas and len(meas) >= 2:
                best = min(meas.values())
                ent["mispredict_ratio"] = \
                    (meas[chosen] / best) if best > 0 else None
            else:
                ent["mispredict_ratio"] = None
        for h in hists.values():
            h["mean"] = h["total"] / h["count"] if h["count"] else 0.0
        return {"counters": counters, "gauges": gauges,
                "histograms": hists, "planner": planner}

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._planner.clear()


_metrics = Metrics()


def get_metrics() -> Metrics:
    return _metrics


def inc(name: str, value: float = 1.0) -> None:
    _metrics.inc(name, value)


def gauge(name: str, value: float) -> None:
    _metrics.gauge(name, value)


def observe(name: str, value: float) -> None:
    _metrics.observe(name, value)


def record_plan(key: str, backend: str, est=None) -> None:
    _metrics.record_plan(key, backend, est)


def record_backend_us(key: str, backend: str, us: float) -> None:
    _metrics.record_backend_us(key, backend, us)


def snapshot() -> Dict[str, Any]:
    return _metrics.snapshot()


def reset() -> None:
    _metrics.reset()
