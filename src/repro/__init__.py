"""SPLIM reproduction — structured in-situ SpGEMM, planned and served.

One import surface for the whole stack (lazily resolved, so ``import
repro`` stays free until a name is touched — the model zoo and serving
engine never tax a kernels-only user):

    import repro
    c = repro.spgemm(a, b)                      # unified SpGEMM front door
    st = repro.make_structure(a, b)             # two-phase symbolic step
    c = repro.spgemm(a, b, structure=st)        # warm numeric path
    layer = repro.SparseLinear(w, sparsity=0.9) # N:M / ELLPACK routed
    eng = repro.ServingEngine(model, params, repro.ServeConfig())

``repro.spgemm`` (core/api.py) documents the shared auto-select semantics;
the legacy per-variant entry points under ``repro.core`` remain stable thin
wrappers.
"""
from __future__ import annotations

import importlib

# name -> module that defines it (resolved lazily, PEP 562)
_NAMES = {
    # unified front door + planning
    "spgemm": "repro.core.api",
    "spgemm_dense": "repro.core.spgemm",
    "make_plan": "repro.plan",
    "make_dist_plan": "repro.plan",
    "make_structure": "repro.plan",
    "make_structure_batched": "repro.plan",
    "plan_spmm_format": "repro.plan",
    "fingerprint": "repro.plan",
    "Plan": "repro.plan",
    "DistPlan": "repro.plan",
    "SpgemmStructure": "repro.plan",
    "StructureCache": "repro.plan",
    # formats + converters + overflow contract
    "Coo": "repro.core.formats",
    "EllCols": "repro.core.formats",
    "EllRows": "repro.core.formats",
    "coo_from_dense": "repro.core.formats",
    "ell_cols_from_dense": "repro.core.formats",
    "ell_rows_from_dense": "repro.core.formats",
    "AccumulatorOverflow": "repro.core.accumulate",
    "check_no_overflow": "repro.core.accumulate",
    "count_products": "repro.core.sccp",
    # N:M fast path
    "NmWeights": "repro.core.nm",
    "nm_from_dense": "repro.core.nm",
    "detect_nm": "repro.core.nm",
    "nm_spmm": "repro.kernels.nm_spmm",
    # models + serving
    "SparseLinear": "repro.models.sparse",
    "SparseMLP": "repro.models.ffn",
    "magnitude_prune": "repro.models.sparse",
    "magnitude_prune_nm": "repro.models.sparse",
    "ServeConfig": "repro.serve.engine",
    "ServingEngine": "repro.serve.engine",
    "SparseGemmBatcher": "repro.serve.engine",
}

# submodules reachable as repro.<name> without deep-importing repro.core.*
_MODULES = {
    "core": "repro.core",
    "hwmodel": "repro.core.hwmodel",
    "hybrid": "repro.core.hybrid",
    "sccp": "repro.core.sccp",
    "kernels": "repro.kernels",
    "plan": "repro.plan",
    "models": "repro.models",
    "serve": "repro.serve",
    "configs": "repro.configs",
    "obs": "repro.obs",
}

__all__ = sorted(set(_NAMES) | set(_MODULES))


def __getattr__(name: str):
    if name in _NAMES:
        return getattr(importlib.import_module(_NAMES[name]), name)
    if name in _MODULES:
        return importlib.import_module(_MODULES[name])
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return __all__
