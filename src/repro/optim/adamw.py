"""AdamW with ZeRO-1 optimizer-state sharding.

Moments are fp32 regardless of param dtype (mixed-precision master state).
ZeRO-1: every moment leaf is additionally sharded over the data axis on the
first free (un-model-sharded, divisible) dimension — the "opt_shard" logical
axis. Under GSPMD the param update then lowers to
reduce-scatter(grads) → sharded update → all-gather(params), the standard
ZeRO-1 schedule, without manual collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import Spec, is_spec
from repro.parallel.sharding import current_rules


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    zero1: bool = True
    warmup_steps: int = 100
    total_steps: int = 10_000


def _moment_axes(spec: Spec) -> Tuple[Optional[str], ...]:
    """Logical axes for a moment leaf: param axes + opt_shard on the first
    free dimension (ZeRO-1)."""
    axes = list(spec.axes)
    for i, a in enumerate(axes):
        if a is None:
            axes[i] = "opt_shard"
            break
    return tuple(axes)


def opt_state_specs(param_specs) -> Any:
    """Spec tree for (mu, nu) mirroring params, with ZeRO-1 axes."""
    def one(s: Spec) -> Spec:
        return Spec(s.shape, _moment_axes(s), init="zeros")
    return {
        "mu": jax.tree.map(one, param_specs, is_leaf=is_spec),
        "nu": jax.tree.map(one, param_specs, is_leaf=is_spec),
        "step": Spec((), (), init="zeros"),
    }


def adamw_init(params) -> Any:
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _shard_moment(x: jax.Array, spec: Optional[Spec]):
    rules = current_rules()
    if rules is None or rules.mesh is None or spec is None:
        return x
    from jax.sharding import NamedSharding
    pspec = rules.resolve(_moment_axes(spec), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, pspec))


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / max(1, cfg.warmup_steps), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 param_specs=None):
    """One AdamW step. param_specs (Spec tree) enables ZeRO-1 constraints."""
    step = state["step"] + 1
    lr = _schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    spec_leaves = (jax.tree.leaves(param_specs, is_leaf=is_spec)
                   if param_specs is not None else None)
    p_leaves, treedef = jax.tree.flatten(params)
    g_leaves = jax.tree.leaves(grads)
    mu_leaves = jax.tree.leaves(state["mu"])
    nu_leaves = jax.tree.leaves(state["nu"])

    new_p, new_mu, new_nu = [], [], []
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    for i, (p, g, mu, nu) in enumerate(zip(p_leaves, g_leaves, mu_leaves, nu_leaves)):
        spec = spec_leaves[i] if spec_leaves is not None else None
        g = g.astype(jnp.float32) * scale
        mu = _shard_moment(cfg.b1 * mu + (1 - cfg.b1) * g, spec)
        nu = _shard_moment(cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g), spec)
        upd = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
        new_mu.append(mu)
        new_nu.append(nu)

    params = jax.tree.unflatten(treedef, new_p)
    state = {"mu": jax.tree.unflatten(treedef, new_mu),
             "nu": jax.tree.unflatten(treedef, new_nu),
             "step": step}
    return params, state, {"grad_norm": gnorm, "lr": lr}
