"""Gradient compression: int8 quantization with error feedback.

Distributed-optimization trick for the data-parallel gradient exchange: the
shard_map trainer (runtime/data_parallel.py) quantizes local gradients to
int8 (per-tensor absmax scale), psums the int8 payload (4× less ICI bytes),
dequantizes, and carries the quantization residual into the next step
(error feedback keeps the method unbiased over time).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import axis_size


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads, axis: str, error=None):
    """Quantize → psum(int8 as int32 accum) → dequantize, with error feedback.

    Returns (mean_grads, new_error). Call inside shard_map over ``axis``.
    """
    n = axis_size(axis)

    def one(g, e):
        g = g + (e if e is not None else 0.0)
        # shards must agree on ONE scale or the int8 lattices are not
        # summable: pmax the absmax (scalar, cheap), then the int32 psum of
        # the shared-scale lattice is exact.
        gmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
        scale = gmax / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = total.astype(jnp.float32) * scale / n
        new_e = g - decompress_int8(q, scale)
        return mean, new_e

    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    means, errs = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = one(g.astype(jnp.float32), e)
        means.append(m)
        errs.append(ne)
    return jax.tree.unflatten(treedef, means), jax.tree.unflatten(treedef, errs)
