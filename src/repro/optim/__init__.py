from .adamw import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from .compress import compress_int8, decompress_int8, compressed_psum_mean

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_specs",
           "compress_int8", "decompress_int8", "compressed_psum_mean"]
