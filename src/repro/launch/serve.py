"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Batched prefill+decode with the continuous-batching engine.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.parallel.sharding import sharding_rules
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch + ("-smoke" if args.smoke else ""))
    model = build_model(cfg)
    mesh = make_host_mesh(args.model_parallel)
    rng = np.random.default_rng(0)
    with sharding_rules(mesh), mesh:
        params = model.init(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, ServeConfig(
            max_new_tokens=args.max_new))
        waves = [args.requests // eng.cfg.max_batch or 1]
        served = 0
        while served < args.requests:
            n = min(eng.cfg.max_batch, args.requests - served)
            prompts = [rng.integers(3, cfg.vocab, size=rng.integers(4, 16))
                       .astype(np.int32) for _ in range(n)]
            outs = eng.generate_batch(prompts)
            served += n
        s = eng.stats
        print(f"[serve] {s['requests']} reqs, {s['tokens']} tokens, "
              f"decode {s['tokens']/max(s['decode_s'],1e-9):.1f} tok/s",
              flush=True)


if __name__ == "__main__":
    main()
