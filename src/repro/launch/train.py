"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the real Trainer (checkpoint/restart, fault tolerance) on whatever
devices this host offers. On a CPU box use a reduced (``--smoke``) config;
on a TPU slice point it at the production mesh with --model-parallel.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.sharding import sharding_rules
from repro.runtime import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    name = args.arch + ("-smoke" if args.smoke else "")
    cfg = get_config(name)
    model = build_model(cfg)
    mesh = make_host_mesh(args.model_parallel)
    print(f"[train] arch={cfg.name} params={model.n_params():,} "
          f"mesh={dict(mesh.shape)}", flush=True)

    def extra(step):
        import numpy as np
        import jax.numpy as jnp
        rng = np.random.default_rng(step)
        if cfg.family == "audio":
            return {"frames": jnp.asarray(rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model), dtype=np.float32))}
        if cfg.family == "vlm":
            return {"patches": jnp.asarray(rng.standard_normal(
                (args.batch, cfg.n_vision_tokens, cfg.d_model), dtype=np.float32))}
        return {}

    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every,
                         global_batch=args.batch, seq_len=args.seq)
    with sharding_rules(mesh), mesh:
        trainer = Trainer(model, tcfg, AdamWConfig(lr=args.lr),
                          extra_batch_fn=extra if cfg.family in ("audio", "vlm") else None)
        out = trainer.run(resume=not args.no_resume)
    print(f"[train] done. final loss "
          f"{out['history'][-1]['loss']:.4f}", flush=True)


if __name__ == "__main__":
    main()
