"""Step-function assembly: jitted train / prefill / serve steps with
shardings derived from the logical-axis rules.

Used by both the real trainers (train.py / serve.py) and the multi-pod
dry-run (dryrun.py), so what we lower in the dry-run is exactly what a real
launch would execute.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCase
from repro.models import build_model
from repro.models.params import abstract_params, is_spec
from repro.optim import AdamWConfig, adamw_init, adamw_update, opt_state_specs
from repro.parallel.sharding import current_rules


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def make_train_step(model, opt_cfg: AdamWConfig):
    specs = model.specs()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, opt_cfg, param_specs=specs)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model, s_max: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, s_max)
    return prefill_step


def make_serve_step(model):
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve_step


# ---------------------------------------------------------------------------
# Sharding trees for non-param inputs
# ---------------------------------------------------------------------------

def batch_shardings(batch_specs: Dict[str, jax.ShapeDtypeStruct]):
    rules = current_rules()
    assert rules is not None and rules.mesh is not None
    out = {}
    for k, v in batch_specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(rules.mesh, rules.resolve(axes, v.shape))
    return out


_CACHE_AXES = {
    # leaf-name -> logical axes by rank (leading layer-stack dims get None)
    "k": ("batch", "seq_shard", None, None),
    "v": ("batch", "seq_shard", None, None),
    "ck": ("batch", None, "heads", None),
    "cv": ("batch", None, "heads", None),
    "latent": ("batch", "seq_shard", None),
    "krope": ("batch", "seq_shard", None),
    "conv": ("batch", None, "ff"),
    "ssm": ("batch", "ff", None),
    "h": ("batch", "ff"),
    "slot_pos": None,
    "pos": None,
}


def cache_shardings(cache_shapes):
    """NamedSharding tree for a decode cache ShapeDtypeStruct tree."""
    rules = current_rules()
    assert rules is not None and rules.mesh is not None

    def one(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = entry.key
                break
        axes = _CACHE_AXES.get(name)
        if axes is None:
            return NamedSharding(rules.mesh, P())
        pad = len(leaf.shape) - len(axes)
        full = (None,) * pad + tuple(axes)
        return NamedSharding(rules.mesh, rules.resolve(full, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ---------------------------------------------------------------------------
# Abstract (no-allocation) argument builders for the dry-run
# ---------------------------------------------------------------------------

def abstract_train_args(model, case: ShapeCase):
    specs = model.specs()
    aparams = abstract_params(specs, jnp.dtype(model.cfg.param_dtype))
    aopt = abstract_params(opt_state_specs(specs), jnp.float32)
    # step counter is int32
    aopt["step"] = jax.ShapeDtypeStruct((), jnp.int32,
                                        sharding=aopt["step"].sharding)
    binput = model.input_specs(case)
    bshard = batch_shardings(binput)
    batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
             for k, v in binput.items()}
    return aparams, aopt, batch


def abstract_decode_args(model, case: ShapeCase):
    aparams = model.abstract_params()
    cache_shapes = jax.eval_shape(
        lambda: model.cache_zeros(case.global_batch, case.seq_len))
    cshard = cache_shardings(cache_shapes)
    acache = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shapes, cshard)
    binput = model.input_specs(case)
    bshard = batch_shardings(binput)
    tokens = jax.ShapeDtypeStruct(binput["tokens"].shape, jnp.int32,
                                  sharding=bshard["tokens"])
    return aparams, acache, tokens


def abstract_prefill_args(model, case: ShapeCase):
    aparams = model.abstract_params()
    binput = model.input_specs(case)
    bshard = batch_shardings(binput)
    batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
             for k, v in binput.items()}
    return aparams, batch


def prefill_out_shardings(model, case: ShapeCase, step):
    """(logits, cache) output shardings — without this the prefill KV-cache
    output materializes replicated (tens of GiB at 32k seq)."""
    rules = current_rules()
    assert rules is not None and rules.mesh is not None
    from jax.sharding import PartitionSpec as P
    aparams, batch = abstract_prefill_args(model, case)
    out_shapes = jax.eval_shape(step, aparams, batch)
    logits_sh = NamedSharding(
        rules.mesh, rules.resolve(("batch", None), out_shapes[0].shape))
    cache_sh = cache_shardings(out_shapes[1])
    return (logits_sh, cache_sh)
