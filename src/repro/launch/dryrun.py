import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build the production mesh ((16,16) single-pod and
(2,16,16) multi-pod), assemble the *real* step function (the same one
train.py / serve.py execute), lower it with ShapeDtypeStruct stand-ins
(zero allocation), compile, and record:

  * memory_analysis()  — per-device bytes (proves the cell fits)
  * cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes   — parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Results land in results/dryrun/<arch>__<shape>__<mesh>.json;
EXPERIMENTS.md §Dry-run and §Roofline are generated from these files.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.compat import cost_analysis
from repro.configs import ARCHS, applicable_shapes, get_config, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (abstract_decode_args, abstract_prefill_args,
                                abstract_train_args, make_prefill_step,
                                make_serve_step, make_train_step)
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.parallel.sharding import sharding_rules

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# HLO collective ops whose operand bytes we account as ICI traffic.
_COLL_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9_\[\]{},/ ]+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(",
)
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([\d,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "f64": 8}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op, by kind."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    count = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        out[kind] += _shape_bytes(m.group(2))
        count[kind] += 1
    return out, count


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if b < 1024:
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.2f}PiB"


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             dispatch: str = None, verbose: bool = True,
             xe_shard: str = None):
    cfg = get_config(arch)
    if dispatch and cfg.moe:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=dispatch,
                                         xe_shard=xe_shard or "both"))
    case = get_shape(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{cfg.name}__{case.name}__{mesh_name}" + (
        f"__{dispatch}" if dispatch else "") + (
        f"__{xe_shard}" if xe_shard else "")
    t0 = time.time()
    with sharding_rules(mesh), mesh:
        model = build_model(cfg)
        if case.kind == "train":
            step = make_train_step(model, AdamWConfig())
            args = abstract_train_args(model, case)
            fn = jax.jit(step, donate_argnums=(0, 1))
        elif case.kind == "prefill":
            step = make_prefill_step(model, s_max=case.seq_len)
            args = abstract_prefill_args(model, case)
            from repro.launch.steps import prefill_out_shardings
            fn = jax.jit(step, out_shardings=prefill_out_shardings(
                model, case, step))
        else:  # decode
            step = make_serve_step(model)
            args = abstract_decode_args(model, case)
            fn = jax.jit(step, donate_argnums=(1,))
        lowered = fn.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = cost_analysis(compiled)
        hlo = compiled.as_text()
    coll, coll_count = collective_bytes(hlo)
    # trip-count-aware analysis (HloCostAnalysis counts while bodies once —
    # wrong by ~n_layers with scan-over-layers; see hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze_hlo
    tc = analyze_hlo(hlo)
    n_dev = mesh.size
    rec = {
        "arch": cfg.name, "shape": case.name, "kind": case.kind,
        "mesh": mesh_name, "n_devices": n_dev,
        "dispatch": dispatch or (cfg.moe.dispatch if cfg.moe else None),
        "seq_len": case.seq_len, "global_batch": case.global_batch,
        "n_params": model.n_params(),
        "active_params": cfg.active_params(),
        "compile_s": round(time.time() - t0, 1),
        "hlo_flops": cost.get("flops", 0.0) if cost else None,
        "hlo_bytes": cost.get("bytes accessed", 0.0) if cost else None,
        "mem_per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "collective_bytes": coll,
        "collective_count": coll_count,
        "hlo_flops_tc": tc["flops"],
        "hlo_bytes_tc": tc["hbm_bytes"],
        "collective_bytes_tc": tc["collective_bytes"],
        "collective_count_tc": tc["collective_count"],
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell}.json").write_text(json.dumps(rec, indent=1))
    if verbose:
        mb = rec["mem_per_device"]
        tot_coll = sum(coll.values())
        print(f"[OK] {cell}: compile={rec['compile_s']}s "
              f"flops={rec['hlo_flops']:.3e} "
              f"args/dev={_fmt_bytes(mb['argument_bytes'] or 0)} "
              f"temp/dev={_fmt_bytes(mb['temp_bytes'] or 0)} "
              f"coll={_fmt_bytes(tot_coll)}", flush=True)
    return rec


def iter_cells():
    for name, cfg in ARCHS.items():
        for case in applicable_shapes(cfg):
            yield name, case.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--dispatch", choices=["ellpack", "sort"])
    ap.add_argument("--moe-xe-shard", choices=["both", "batch", "expert"])
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()
    out_dir = Path(args.out)

    if args.all:
        cells = list(iter_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    pods = []
    if not args.multi_pod_only:
        pods.append(False)
    if not args.single_pod_only:
        pods.append(True)
    if args.multi_pod:
        pods = [True]

    failures = []
    for arch, shape in cells:
        for mp in pods:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            suffix = f"__{args.dispatch}" if args.dispatch else ""
            done = out_dir / f"{arch}__{shape}__{mesh_name}{suffix}.json"
            if args.skip_done and done.exists():
                print(f"[skip] {done.name}", flush=True)
                continue
            try:
                run_cell(arch, shape, mp, out_dir, dispatch=args.dispatch,
                         xe_shard=args.moe_xe_shard)
            except Exception as e:  # record and continue the sweep
                failures.append((arch, shape, mesh_name, repr(e)))
                print(f"[FAIL] {arch}__{shape}__{mesh_name}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
