"""Trip-count-aware analysis of optimized HLO (fixes XLA cost_analysis).

``HloCostAnalysis`` counts a while-loop body **once**; with scan-over-layers
that under-reports FLOPs/bytes/collective traffic by ~n_layers. This module
parses the optimized HLO text, recovers every while loop's trip count from
its condition's comparison constant, and accumulates:

  * dot FLOPs           2 · prod(output dims) · contraction size
  * HBM traffic         Σ over *top-level* instructions of
                        (operand bytes + output bytes) — fusion internals
                        stay in registers/VMEM, so fusions count only their
                        boundary, which is the roofline convention
  * collective bytes    output-shape bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute

each multiplied by the product of enclosing trip counts.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
          "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\))|(?:[\w\[\]{},\/]+))\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:condition|body|to|calls)=%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[List[int]]:
    out = []
    for _, dims in _SHAPE_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",") if d])
    return out


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[dict]] = {}
        self.shape_of: Dict[str, str] = {}
        self._parse(text)

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            # computation headers: "%name (args) -> type {"  or "ENTRY ..."
            if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
                cur = m.group(1) if m else None
                if line.startswith("ENTRY"):
                    self.entry = cur
                self.computations[cur] = []
                continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rest = m.group(1), m.group(2)
            om = _OP_RE.match(rest)
            if not om:
                continue
            type_str, op, tail = om.group(1), om.group(2), om.group(3)
            self.shape_of[name] = type_str
            self.computations[cur].append(
                {"name": name, "type": type_str, "op": op, "tail": tail,
                 "line": line})

    # -- trip counts -----------------------------------------------------------
    def trip_count(self, cond_comp: str) -> int:
        """Largest s32 constant in the condition computation (scan bound)."""
        best = 1
        for ins in self.computations.get(cond_comp, []):
            if ins["op"] == "constant" and ins["type"].startswith("s32"):
                mm = re.search(r"constant\((\-?\d+)\)", ins["line"])
                if mm:
                    best = max(best, int(mm.group(1)))
        return best

    # -- per-instruction costs ---------------------------------------------------
    def _dot_flops(self, ins) -> float:
        out_dims = _shape_dims(ins["type"])
        out_n = 1
        for d in (out_dims[0] if out_dims else []):
            out_n *= d
        ops = _OPERAND_RE.findall(ins["tail"])
        lhs = self.shape_of.get(ops[0]) if ops else None
        k = 1
        mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins["line"])
        if lhs and mm:
            dims = _shape_dims(lhs)
            if dims:
                for idx in mm.group(1).split(","):
                    if idx:
                        k *= dims[0][int(idx)]
        # batch dims are included in out_n already
        return 2.0 * out_n * k

    def _hbm_bytes(self, ins) -> float:
        total = _shape_bytes(ins["type"])
        for op_name in _OPERAND_RE.findall(ins["tail"]):
            if op_name in self.shape_of:
                total += _shape_bytes(self.shape_of[op_name])
        return float(total)

    # -- recursive accumulation ---------------------------------------------------
    def analyze(self, comp: str = None, _memo=None) -> Dict[str, float]:
        if comp is None:
            comp = self.entry
        if _memo is None:
            _memo = {}
        if comp in _memo:
            return _memo[comp]
        acc = {"flops": 0.0, "hbm_bytes": 0.0,
               **{f"coll_{c}": 0.0 for c in COLLECTIVES},
               "coll_count": 0.0}
        for ins in self.computations.get(comp, []):
            op = ins["op"]
            if op == "dot":
                acc["flops"] += self._dot_flops(ins)
                acc["hbm_bytes"] += self._hbm_bytes(ins)
            elif op in ("convolution",):
                acc["flops"] += 2.0 * _shape_bytes(ins["type"])  # rough
                acc["hbm_bytes"] += self._hbm_bytes(ins)
            elif op == "while":
                calls = _CALLS_RE.findall(ins["line"])
                cond = body = None
                mm = re.search(r"condition=%([\w.\-]+)", ins["line"])
                bb = re.search(r"body=%([\w.\-]+)", ins["line"])
                if mm and bb:
                    trips = self.trip_count(mm.group(1))
                    sub = self.analyze(bb.group(1), _memo)
                    for k in acc:
                        acc[k] += trips * sub[k]
            elif op in ("call", "async-start"):
                mm = re.search(r"to=%([\w.\-]+)", ins["line"])
                if mm:
                    sub = self.analyze(mm.group(1), _memo)
                    for k in acc:
                        acc[k] += sub[k]
            elif op == "fusion":
                acc["hbm_bytes"] += self._hbm_bytes(ins)
                # dots inside CPU loop-fusions are rare; count if present
                mm = re.search(r"calls=%([\w.\-]+)", ins["line"])
                if mm:
                    sub = self.analyze(mm.group(1), _memo)
                    acc["flops"] += sub["flops"]
                    for c in COLLECTIVES:
                        acc[f"coll_{c}"] += sub[f"coll_{c}"]
            elif op == "conditional":
                # count the larger branch (upper bound)
                branches = re.findall(r"(?:true_computation|false_computation|branch_computations=\{)[^,)]*%([\w.\-]+)", ins["line"])
                subs = [self.analyze(b, _memo) for b in branches]
                if subs:
                    for k in acc:
                        acc[k] += max(s[k] for s in subs)
            elif any(op.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if op.startswith(c))
                b = _shape_bytes(ins["type"])
                acc[f"coll_{kind}"] += b
                acc["coll_count"] += 1
                acc["hbm_bytes"] += self._hbm_bytes(ins)
            elif op in ("dynamic-slice", "dynamic-update-slice", "gather",
                        "scatter", "copy", "transpose", "reduce", "sort",
                        "concatenate", "pad", "reverse", "select-and-scatter"):
                acc["hbm_bytes"] += self._hbm_bytes(ins)
        _memo[comp] = acc
        return acc


def analyze_hlo(text: str) -> Dict[str, float]:
    mod = HloModule(text)
    acc = mod.analyze()
    out = {"flops": acc["flops"], "hbm_bytes": acc["hbm_bytes"],
           "collective_bytes": {c: acc[f"coll_{c}"] for c in COLLECTIVES},
           "collective_count": acc["coll_count"]}
    return out
